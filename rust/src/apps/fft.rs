//! Distributed 4-step FFT (§VI-A) — the end-to-end driver that composes
//! all three layers:
//!
//! * **L1/L2**: the local DFT stages are Pallas kernels inside a JAX
//!   graph, AOT-lowered to HLO text by `python/compile/aot.py`;
//! * **runtime**: Rust loads and executes them via PJRT
//!   ([`crate::runtime::PjrtRuntime`]) — Python is never on this path;
//! * **L3**: the matrix transpose between the stages is a non-uniform
//!   all-to-allv through any [`AlgoKind`] (non-uniform whenever P does
//!   not divide n1/n2 — exactly FFTW's situation the paper describes).
//!
//! Math (decimation in time, N = n1·n2, `x[j1 + n1·j2]`):
//!   `X[k2 + n2·k1] = Σ_{j1} W_{n1}^{j1·k1} [ W_N^{j1·k2} ·
//!                    Σ_{j2} x[j1 + n1·j2] W_{n2}^{j2·k2} ]`
//! Stage 1 (row-partitioned): per-row DFT_{n2} + twiddle W_N^{j1·k2}.
//! Transpose: rows → columns (the all-to-allv).
//! Stage 2 (column-partitioned): per-column DFT_{n1}.
//!
//! The result is validated against a sequential f64 DFT oracle.

use std::f64::consts::PI;
use std::path::PathBuf;
use std::sync::Arc;

use crate::algos::AlgoKind;
use crate::comm::{Block, DataBuf, Engine, Phase, Topology};
use crate::error::{Result, TunaError};
use crate::model::MachineProfile;
use crate::runtime::PjrtRuntime;
use crate::util::prng::Pcg64;

/// Which engine computes the local DFT stages.
pub enum FftBackend {
    /// Pure-Rust naive DFT (always available; also the per-shape fallback
    /// when an artifact is missing from the manifest).
    Naive,
    /// PJRT executing the AOT-lowered Pallas/JAX artifacts from `dir`.
    Pjrt { dir: PathBuf },
}

impl FftBackend {
    /// Use PJRT when this build carries the `pjrt` feature and
    /// `artifacts/manifest.tsv` exists, else naive.
    pub fn auto() -> FftBackend {
        let dir = PathBuf::from("artifacts");
        if crate::runtime::pjrt_available() && crate::runtime::artifacts_present(&dir) {
            FftBackend::Pjrt { dir }
        } else {
            FftBackend::Naive
        }
    }
}

/// Result of a distributed FFT run.
#[derive(Clone, Debug)]
pub struct FftReport {
    /// max |X - X_ref| / max |X_ref| against the f64 oracle.
    pub max_err: f64,
    /// Simulated total (compute charged to rank clocks + transpose).
    pub makespan: f64,
    /// Simulated transpose (communication) time.
    pub comm_time: f64,
    /// Host wallclock spent in local DFT stages (max over ranks, both
    /// stages) — what is charged to the virtual clocks.
    pub compute_time: f64,
    /// Host wallclock for the whole run.
    pub wall: f64,
    /// Human-readable backend description.
    pub backend: String,
    /// Per-rank host seconds charged to stage 1 on the virtual clocks —
    /// the compute budget the overlap twin divides across segments.
    pub stage1_secs: Vec<f64>,
}

/// Contiguous partition of `n` items over `p` ranks: first `n % p` ranks
/// get one extra — non-uniform whenever `p` does not divide `n`.
pub fn partition(n: usize, p: usize) -> Vec<(usize, usize)> {
    let base = n / p;
    let extra = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        out.push((start, len));
        start += len;
    }
    out
}

/// Complex matrix in split re/im layout, row-major `rows x cols`.
#[derive(Clone, Debug, Default)]
pub struct CMat {
    pub rows: usize,
    pub cols: usize,
    pub re: Vec<f32>,
    pub im: Vec<f32>,
}

impl CMat {
    pub fn zeros(rows: usize, cols: usize) -> CMat {
        CMat {
            rows,
            cols,
            re: vec![0.0; rows * cols],
            im: vec![0.0; rows * cols],
        }
    }

    #[inline]
    pub fn idx(&self, r: usize, c: usize) -> usize {
        r * self.cols + c
    }
}

/// DFT matrix F_n[j][k] = W_n^{jk}, W_n = exp(-2πi/n), as split f32.
pub fn dft_matrix(n: usize) -> CMat {
    let mut m = CMat::zeros(n, n);
    for j in 0..n {
        for k in 0..n {
            let ang = -2.0 * PI * (j as f64) * (k as f64) / n as f64;
            let i = j * n + k;
            m.re[i] = ang.cos() as f32;
            m.im[i] = ang.sin() as f32;
        }
    }
    m
}

/// Twiddle block T[j1][k2] = W_N^{(row0+j1)·k2} for local rows.
pub fn twiddles(row0: usize, rows: usize, n2: usize, n_total: usize) -> CMat {
    let mut t = CMat::zeros(rows, n2);
    for j in 0..rows {
        for k in 0..n2 {
            let ang = -2.0 * PI * ((row0 + j) as f64) * (k as f64) / n_total as f64;
            let i = j * n2 + k;
            t.re[i] = ang.cos() as f32;
            t.im[i] = ang.sin() as f32;
        }
    }
    t
}

/// Naive complex matmul `A (r x k) @ B (k x c)`, optionally Hadamard-
/// multiplied by twiddles `T (r x c)`.
fn cmatmul(a: &CMat, b: &CMat, t: Option<&CMat>) -> CMat {
    assert_eq!(a.cols, b.rows);
    let mut out = CMat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for kk in 0..a.cols {
            let ar = a.re[i * a.cols + kk] as f64;
            let ai = a.im[i * a.cols + kk] as f64;
            for j in 0..b.cols {
                let br = b.re[kk * b.cols + j] as f64;
                let bi = b.im[kk * b.cols + j] as f64;
                out.re[i * out.cols + j] += (ar * br - ai * bi) as f32;
                out.im[i * out.cols + j] += (ar * bi + ai * br) as f32;
            }
        }
    }
    if let Some(t) = t {
        assert_eq!((t.rows, t.cols), (out.rows, out.cols));
        for i in 0..out.re.len() {
            let (r, im) = (out.re[i] as f64, out.im[i] as f64);
            let (tr, ti) = (t.re[i] as f64, t.im[i] as f64);
            out.re[i] = (r * tr - im * ti) as f32;
            out.im[i] = (r * ti + im * tr) as f32;
        }
    }
    out
}

/// Local-stage compute dispatcher: PJRT artifact when available, naive
/// fallback otherwise.
struct StageCompute {
    runtime: Option<PjrtRuntime>,
    /// Shapes that fell back to naive (artifact missing).
    fallbacks: Vec<String>,
}

impl StageCompute {
    fn new(backend: &FftBackend) -> Result<StageCompute> {
        let runtime = match backend {
            FftBackend::Naive => None,
            FftBackend::Pjrt { dir } => Some(PjrtRuntime::open(dir)?),
        };
        Ok(StageCompute {
            runtime,
            fallbacks: Vec::new(),
        })
    }

    fn describe(&self) -> String {
        match &self.runtime {
            None => "naive rust DFT".to_string(),
            Some(rt) => {
                if self.fallbacks.is_empty() {
                    format!("PJRT ({}) via AOT Pallas/JAX artifacts", rt.platform())
                } else {
                    format!(
                        "PJRT ({}) with naive fallback for shapes {:?}",
                        rt.platform(),
                        self.fallbacks
                    )
                }
            }
        }
    }

    /// Stage 1: (A @ F_{n2}) ⊙ T for local rows.
    fn stage1(&mut self, a: &CMat, f: &CMat, t: &CMat) -> Result<CMat> {
        let name = format!("fft_stage1_{}x{}", a.rows, a.cols);
        if a.rows > 0 {
            if let Some(rt) = &mut self.runtime {
                if rt.has(&name) {
                    let dims_a = [a.rows as i64, a.cols as i64];
                    let dims_f = [f.rows as i64, f.cols as i64];
                    let out = rt.execute_f32(
                        &name,
                        &[
                            (&a.re, &dims_a),
                            (&a.im, &dims_a),
                            (&f.re, &dims_f),
                            (&f.im, &dims_f),
                            (&t.re, &dims_a),
                            (&t.im, &dims_a),
                        ],
                    )?;
                    return Ok(CMat {
                        rows: a.rows,
                        cols: a.cols,
                        re: out[0].clone(),
                        im: out[1].clone(),
                    });
                }
                if !self.fallbacks.contains(&name) {
                    self.fallbacks.push(name);
                }
            }
        }
        Ok(cmatmul(a, f, Some(t)))
    }

    /// Stage 2: F_{n1} @ A for local columns.
    fn stage2(&mut self, f: &CMat, a: &CMat) -> Result<CMat> {
        let name = format!("fft_stage2_{}x{}", f.rows, a.cols);
        if a.cols > 0 {
            if let Some(rt) = &mut self.runtime {
                if rt.has(&name) {
                    let dims_a = [a.rows as i64, a.cols as i64];
                    let dims_f = [f.rows as i64, f.cols as i64];
                    let out = rt.execute_f32(
                        &name,
                        &[
                            (&f.re, &dims_f),
                            (&f.im, &dims_f),
                            (&a.re, &dims_a),
                            (&a.im, &dims_a),
                        ],
                    )?;
                    return Ok(CMat {
                        rows: f.rows,
                        cols: a.cols,
                        re: out[0].clone(),
                        im: out[1].clone(),
                    });
                }
                if !self.fallbacks.contains(&name) {
                    self.fallbacks.push(name);
                }
            }
        }
        Ok(cmatmul(f, a, None))
    }
}

/// Encode every destination's column block of `z` (complex f32 pairs,
/// row-major within the block) into one shared arena, handing back
/// zero-copy per-destination views: one allocation and one host-copy
/// charge per rank per transpose instead of one per destination.
fn encode_col_blocks(z: &CMat, cols_part: &[(usize, usize)]) -> Vec<DataBuf> {
    let total: usize = cols_part.iter().map(|&(_, cols)| z.rows * cols * 8).sum();
    let mut arena = Vec::with_capacity(total);
    let mut bounds = Vec::with_capacity(cols_part.len());
    for &(c0, cols) in cols_part {
        let start = arena.len() as u64;
        for r in 0..z.rows {
            for c in c0..c0 + cols {
                let i = z.idx(r, c);
                arena.extend_from_slice(&z.re[i].to_le_bytes());
                arena.extend_from_slice(&z.im[i].to_le_bytes());
            }
        }
        bounds.push((start, arena.len() as u64 - start));
    }
    let master = DataBuf::from_vec(arena);
    bounds
        .into_iter()
        .map(|(off, len)| master.slice(off, len))
        .collect()
}

fn f32_at(bytes: &[u8], i: usize) -> f32 {
    f32::from_le_bytes([bytes[i], bytes[i + 1], bytes[i + 2], bytes[i + 3]])
}

/// Sequential f64 DFT oracle.
pub fn naive_dft(x_re: &[f64], x_im: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = x_re.len();
    let mut out_re = vec![0.0; n];
    let mut out_im = vec![0.0; n];
    for k in 0..n {
        let mut sr = 0.0;
        let mut si = 0.0;
        for j in 0..n {
            let ang = -2.0 * PI * (j as f64) * (k as f64) / n as f64;
            let (c, s) = (ang.cos(), ang.sin());
            sr += x_re[j] * c - x_im[j] * s;
            si += x_re[j] * s + x_im[j] * c;
        }
        out_re[k] = sr;
        out_im[k] = si;
    }
    (out_re, out_im)
}

/// Run the distributed FFT of a deterministic pseudo-random signal of
/// length `n1 * n2` over `p` ranks (`q` per node) using `kind` for the
/// transpose. Returns the validated report.
pub fn run_distributed_fft(
    profile: &MachineProfile,
    p: usize,
    q: usize,
    n1: usize,
    n2: usize,
    kind: &AlgoKind,
    backend: FftBackend,
) -> Result<FftReport> {
    let wall0 = std::time::Instant::now();
    let n_total = n1 * n2;
    kind.check(p, q)?;
    if p > n1.max(2) || p > n2.max(2) {
        return Err(TunaError::config(format!(
            "P={p} too large for N={n1}x{n2} decomposition"
        )));
    }

    // Input signal x, complex f32 in [-1, 1].
    let mut rng = Pcg64::new(0xFF7 ^ n_total as u64, 0);
    let x_re: Vec<f32> = (0..n_total)
        .map(|_| (rng.next_f64() * 2.0 - 1.0) as f32)
        .collect();
    let x_im: Vec<f32> = (0..n_total)
        .map(|_| (rng.next_f64() * 2.0 - 1.0) as f32)
        .collect();

    let rows_part = partition(n1, p);
    let cols_part = partition(n2, p);
    let f_n2 = dft_matrix(n2);
    let f_n1 = dft_matrix(n1);

    // ---- stage 1 on the host, per rank (PJRT or naive), timed.
    let mut compute = StageCompute::new(&backend)?;

    // Warm-up: compile every distinct executable shape once so per-rank
    // timings measure execution, not PJRT compilation (which would
    // otherwise be charged to whichever rank runs a shape first and show
    // up as artificial compute skew in the virtual clocks).
    {
        let mut seen_rows: Vec<usize> = Vec::new();
        for &(r0, rows) in &rows_part {
            if rows > 0 && !seen_rows.contains(&rows) {
                seen_rows.push(rows);
                let a = CMat::zeros(rows, n2);
                let t = twiddles(r0, rows, n2, n_total);
                let _ = compute.stage1(&a, &f_n2, &t)?;
            }
        }
        let mut seen_cols: Vec<usize> = Vec::new();
        for &(_, cols) in &cols_part {
            if cols > 0 && !seen_cols.contains(&cols) {
                seen_cols.push(cols);
                let a = CMat::zeros(n1, cols);
                let _ = compute.stage2(&f_n1, &a)?;
            }
        }
    }

    let mut z_locals: Vec<CMat> = Vec::with_capacity(p);
    let mut t1 = vec![0.0f64; p];
    for (rank, &(r0, rows)) in rows_part.iter().enumerate() {
        let t = std::time::Instant::now();
        // M_local[j][c] = x[(r0+j) + n1*c].
        let mut m = CMat::zeros(rows, n2);
        for j in 0..rows {
            for c in 0..n2 {
                let i = (r0 + j) + n1 * c;
                m.re[j * n2 + c] = x_re[i];
                m.im[j * n2 + c] = x_im[i];
            }
        }
        let tw = twiddles(r0, rows, n2, n_total);
        z_locals.push(compute.stage1(&m, &f_n2, &tw)?);
        t1[rank] = t.elapsed().as_secs_f64();
    }
    let z_locals = Arc::new(z_locals);
    let t1 = Arc::new(t1);

    // ---- transpose on the engine: row partition -> column partition.
    let engine = Engine::new(profile.clone(), Topology::new(p, q));
    let kind_c = *kind;
    let rows_part_c = rows_part.clone();
    let cols_part_c = cols_part.clone();
    let zs = z_locals.clone();
    let t1c = t1.clone();
    let res = engine.run(move |ctx| {
        let me = ctx.rank();
        ctx.phase_mark();
        ctx.compute(t1c[me]);
        ctx.phase_lap(Phase::Compute);
        let z = &zs[me];
        let blocks: Vec<Block> = encode_col_blocks(z, &cols_part_c)
            .into_iter()
            .enumerate()
            .map(|(d, data)| Block::new(me, d, data))
            .collect();
        let comm0 = ctx.now();
        let (recv, _) = kind_c.dispatch(ctx, blocks);
        let comm = ctx.now() - comm0;

        // Assemble Z_cols: n1 x my_cols from origin row ranges.
        let (_c0, my_cols) = cols_part_c[me];
        let mut zc = CMat::zeros(n1, my_cols);
        for b in &recv {
            let (r0, rows) = rows_part_c[b.origin as usize];
            // Read in place at the sink; copies only if some algorithm
            // fragmented the rope (none of ours do).
            let buf = b.data.to_contiguous();
            let bytes: &[u8] = buf.as_ref();
            assert_eq!(bytes.len(), rows * my_cols * 8, "transpose block size");
            let mut off = 0;
            for r in 0..rows {
                for c in 0..my_cols {
                    let i = zc.idx(r0 + r, c);
                    zc.re[i] = f32_at(bytes, off);
                    zc.im[i] = f32_at(bytes, off + 4);
                    off += 8;
                }
            }
        }
        (zc, comm)
    });

    let comm_time = res.ranks.iter().map(|r| r.value.1).fold(0.0f64, f64::max);
    let engine_makespan = res.makespan;

    // ---- stage 2 on the host, per rank, timed.
    let mut t2_max = 0.0f64;
    let mut x_out_re = vec![0.0f32; n_total];
    let mut x_out_im = vec![0.0f32; n_total];
    for (rank, r) in res.ranks.into_iter().enumerate() {
        let (zc, _) = r.value;
        let t = std::time::Instant::now();
        let out = compute.stage2(&f_n1, &zc)?;
        t2_max = t2_max.max(t.elapsed().as_secs_f64());
        let (c0, cols) = cols_part[rank];
        // out[k1][c] = X[(c0+c) + n2*k1]
        for k1 in 0..n1 {
            for c in 0..cols {
                let k = (c0 + c) + n2 * k1;
                x_out_re[k] = out.re[k1 * cols + c];
                x_out_im[k] = out.im[k1 * cols + c];
            }
        }
    }

    // ---- validate against the f64 oracle.
    let xr64: Vec<f64> = x_re.iter().map(|&v| v as f64).collect();
    let xi64: Vec<f64> = x_im.iter().map(|&v| v as f64).collect();
    let (ref_re, ref_im) = naive_dft(&xr64, &xi64);
    let scale = ref_re
        .iter()
        .zip(&ref_im)
        .map(|(r, i)| (r * r + i * i).sqrt())
        .fold(0.0f64, f64::max);
    let mut max_err = 0.0f64;
    for k in 0..n_total {
        let dr = x_out_re[k] as f64 - ref_re[k];
        let di = x_out_im[k] as f64 - ref_im[k];
        max_err = max_err.max((dr * dr + di * di).sqrt());
    }
    let rel_err = max_err / (scale + 1e-30);
    if rel_err > 5e-3 {
        return Err(TunaError::validation(format!(
            "FFT mismatch: relative error {rel_err:.3e} (N={n1}x{n2}, P={p})"
        )));
    }

    let t1_max = t1.iter().fold(0.0f64, |a, &b| a.max(b));
    Ok(FftReport {
        max_err: rel_err,
        makespan: engine_makespan + t2_max,
        comm_time,
        compute_time: t1_max + t2_max,
        wall: wall0.elapsed().as_secs_f64(),
        backend: compute.describe(),
        stage1_secs: t1.to_vec(),
    })
}

/// Timing twin of [`run_distributed_fft`] under segmented overlap:
/// blocking vs pipelined accounting of the *same* FFT.
#[derive(Clone, Debug)]
pub struct FftOverlapReport {
    /// The validated blocking run the twin is derived from (numerics are
    /// computed — and checked against the oracle — exactly once, here).
    pub base: FftReport,
    /// Segment count K of the phantom timing runs.
    pub segments: usize,
    /// Makespan with per-slab DFTs serialized before each exchange
    /// segment (overlap=false).
    pub blocking_makespan: f64,
    /// Makespan with slab-i DFT interleaved into slab-(i−1)'s exchange
    /// (overlap=true).
    pub pipelined_makespan: f64,
    /// Comm seconds program order stalled on, blocking run (summed over
    /// ranks).
    pub exposed_blocking: f64,
    /// Same, pipelined run — the hiding the pipeline buys is
    /// `exposed_blocking - exposed_pipelined`, measured not inferred.
    pub exposed_pipelined: f64,
    /// Comm seconds hidden behind host progress in the pipelined run.
    pub hidden_pipelined: f64,
}

/// Re-run the FFT's transpose as a segmented phantom collective, twice —
/// blocking and pipelined — charging each rank's measured stage-1 cost
/// in K per-slab slices ([`SegmentCompute::PerRank`]). The transpose
/// counts matrix is reconstructed exactly (`rows(src) x cols(dst)`
/// complex-f32 blocks), so both timing runs exchange the bytes the
/// validated run exchanged; only the schedule differs. The numerics run
/// once, in the blocking base run.
pub fn run_distributed_fft_overlap(
    profile: &MachineProfile,
    p: usize,
    q: usize,
    n1: usize,
    n2: usize,
    kind: &AlgoKind,
    backend: FftBackend,
    segments: usize,
) -> Result<FftOverlapReport> {
    use crate::algos::{run_alltoallv_segmented, SegmentCompute};
    use crate::workload::BlockSizes;
    if segments == 0 {
        return Err(TunaError::config(
            "segments must be >= 1 (segments=1 is the unsegmented run)",
        ));
    }
    let base = run_distributed_fft(profile, p, q, n1, n2, kind, backend)?;

    // Transpose byte matrix: rank r holds rows(r) of stage-1 output and
    // sends its intersection with dst's column block, 8 bytes per
    // complex f32 element.
    let rows_part = partition(n1, p);
    let cols_part = partition(n2, p);
    let matrix: Vec<Vec<u64>> = rows_part
        .iter()
        .map(|&(_, rows)| {
            cols_part
                .iter()
                .map(|&(_, cols)| (rows * cols * 8) as u64)
                .collect()
        })
        .collect();
    let sizes = BlockSizes::from_dense(matrix);

    let engine = Engine::new(profile.clone(), Topology::try_new(p, q)?);
    let t1 = base.stage1_secs.clone();
    let per_slab = move |rank: usize, _segment: usize| t1[rank] / segments as f64;
    let compute = SegmentCompute::PerRank(&per_slab);
    let blocking = run_alltoallv_segmented(&engine, kind, &sizes, segments, false, &compute)?;
    let pipelined = run_alltoallv_segmented(&engine, kind, &sizes, segments, true, &compute)?;
    Ok(FftOverlapReport {
        base,
        segments,
        blocking_makespan: blocking.makespan,
        pipelined_makespan: pipelined.makespan,
        exposed_blocking: blocking.counters.exposed_comm,
        exposed_pipelined: pipelined.counters.exposed_comm,
        hidden_pipelined: pipelined.counters.hidden_comm,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_everything() {
        for (n, p) in [(64, 8), (60, 8), (7, 3), (8, 8)] {
            let parts = partition(n, p);
            assert_eq!(parts.len(), p);
            let total: usize = parts.iter().map(|p| p.1).sum();
            assert_eq!(total, n);
            let mut pos = 0;
            for &(start, len) in &parts {
                assert_eq!(start, pos);
                pos += len;
            }
        }
    }

    #[test]
    fn dft_matrix_first_row_is_ones() {
        let f = dft_matrix(8);
        for k in 0..8 {
            assert!((f.re[k] - 1.0).abs() < 1e-6);
            assert!(f.im[k].abs() < 1e-6);
        }
    }

    #[test]
    fn naive_dft_of_impulse_is_flat() {
        let mut re = vec![0.0; 16];
        re[0] = 1.0;
        let im = vec![0.0; 16];
        let (or, oi) = naive_dft(&re, &im);
        for k in 0..16 {
            assert!((or[k] - 1.0).abs() < 1e-12);
            assert!(oi[k].abs() < 1e-12);
        }
    }

    #[test]
    fn distributed_fft_matches_oracle_uniform() {
        let rep = run_distributed_fft(
            &MachineProfile::test_flat(),
            4,
            2,
            16,
            16,
            &AlgoKind::Tuna { radix: 2 },
            FftBackend::Naive,
        )
        .unwrap();
        assert!(rep.max_err < 1e-4, "err {}", rep.max_err);
        assert!(rep.comm_time > 0.0);
    }

    #[test]
    fn distributed_fft_nonuniform_split() {
        // 4 ranks over n2=15 columns: 4,4,4,3 — genuinely non-uniform
        // blocks, the paper's FFTW scenario.
        let rep = run_distributed_fft(
            &MachineProfile::test_flat(),
            4,
            2,
            16,
            15,
            &AlgoKind::hier_coalesced(2, 1),
            FftBackend::Naive,
        )
        .unwrap();
        assert!(rep.max_err < 1e-4, "err {}", rep.max_err);
    }

    #[test]
    fn pipelined_fft_hides_comm_the_blocking_run_exposes() {
        let rep = run_distributed_fft_overlap(
            &MachineProfile::test_flat(),
            4,
            2,
            16,
            16,
            &AlgoKind::Tuna { radix: 2 },
            FftBackend::Naive,
            4,
        )
        .unwrap();
        // Numerics are untouched: the base run validated against the
        // oracle like any blocking run.
        assert!(rep.base.max_err < 1e-4, "err {}", rep.base.max_err);
        assert_eq!(rep.base.stage1_secs.len(), 4);
        assert!(rep.base.stage1_secs.iter().all(|&t| t > 0.0));
        // The blocking twin exposes its exchange; the pipeline hides
        // real slab-DFT seconds inside it — measured, not inferred.
        assert!(rep.exposed_blocking > 0.0);
        assert!(
            rep.exposed_pipelined < rep.exposed_blocking,
            "pipeline hid nothing: exposed {} vs blocking {}",
            rep.exposed_pipelined,
            rep.exposed_blocking
        );
        assert!(rep.hidden_pipelined > 0.0);
        assert!(
            rep.pipelined_makespan <= rep.blocking_makespan,
            "pipelined {} > blocking {}",
            rep.pipelined_makespan,
            rep.blocking_makespan
        );
        // segments=0 is a typed config error, not a panic.
        let e = run_distributed_fft_overlap(
            &MachineProfile::test_flat(),
            4,
            2,
            16,
            16,
            &AlgoKind::Tuna { radix: 2 },
            FftBackend::Naive,
            0,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("segments"), "{e}");
    }

    #[test]
    fn works_across_algorithms() {
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::Pairwise,
            AlgoKind::Scattered { block_count: 2 },
            AlgoKind::Tuna { radix: 4 },
        ] {
            let rep = run_distributed_fft(
                &MachineProfile::test_flat(),
                4,
                2,
                8,
                8,
                &kind,
                FftBackend::Naive,
            )
            .unwrap();
            assert!(rep.max_err < 1e-4, "{kind:?}: err {}", rep.max_err);
        }
    }
}
