//! Selector accuracy against exhaustive measurement (the PR 1 acceptance
//! bar): on the Fig. 9 grid — P = 256, S from 16 B to 64 KiB — the
//! model-ranked top-1 TuNA candidate must land within 15% of the
//! exhaustive engine-sweep best. This is what justifies replacing
//! argmin sweeps with the cost model at paper scale.
//!
//! PR 2 extends the grid with a skewed (power-law) workload: the model
//! only sees the mean block size, so under heavy skew it gets a looser —
//! but still bounded — accuracy budget, and the engine-refined selection
//! path (`skewed=true`) exists precisely to close that gap.

use tuna::algos::{run_alltoallv, select, tuning, AlgoKind};
use tuna::comm::{Engine, Topology};
use tuna::model::MachineProfile;
use tuna::workload::{BlockSizes, Dist};

#[test]
fn model_top1_within_15pct_of_engine_best_on_fig9_grid() {
    let p = 256;
    let q = 8; // the quick-grid Fig. 9 topology
    let profile = MachineProfile::fugaku();
    let engine = Engine::new(profile.clone(), Topology::new(p, q));
    let candidates: Vec<AlgoKind> = tuning::radix_candidates(p)
        .into_iter()
        .map(|radix| AlgoKind::Tuna { radix })
        .collect();

    for s in [16u64, 512, 2048, 16384, 65536] {
        let sizes = BlockSizes::generate(p, Dist::Uniform { max: s }, 0xF19);
        let mean = sizes.mean_size();

        // The selector's analytic pick.
        let ranked = select::model_rank(&profile, engine.topo, mean, &candidates);
        let top1 = ranked[0].kind;

        // Exhaustive engine sweep over the same radix grid + workload.
        let mut best = f64::INFINITY;
        let mut t_top1 = f64::NAN;
        for kind in &candidates {
            let t = run_alltoallv(&engine, kind, &sizes, false).unwrap().makespan;
            if *kind == top1 {
                t_top1 = t;
            }
            best = best.min(t);
        }
        assert!(
            t_top1.is_finite(),
            "S={s}: model pick {} not in the sweep grid",
            top1.name()
        );
        assert!(
            t_top1 <= best * 1.15,
            "S={s}: selector picked {} at {t_top1:.6e}s, engine best is {best:.6e}s \
             ({:.1}% over the 15% budget)",
            top1.name(),
            100.0 * (t_top1 / best - 1.0)
        );
    }
}

#[test]
fn model_top1_bounded_on_skewed_grid_point() {
    // One skewed cell of the grid: a Fig. 16(b)-style power law at
    // P = 256. The mean-block-only model cannot see the tail, so the
    // budget is 35% here (vs 15% for uniform) — tight enough to prove the
    // ranking stays meaningful under skew, loose enough to acknowledge
    // that exact skew robustness is the engine-refinement stage's job.
    let p = 256;
    let q = 8;
    let profile = MachineProfile::fugaku();
    let engine = Engine::new(profile.clone(), Topology::new(p, q));
    let candidates: Vec<AlgoKind> = tuning::radix_candidates(p)
        .into_iter()
        .map(|radix| AlgoKind::Tuna { radix })
        .collect();

    let dist = Dist::PowerLaw { max: 2048, skew: 4.0 };
    let sizes = BlockSizes::generate(p, dist, 0xF19);
    let mean = sizes.mean_size();

    let ranked = select::model_rank(&profile, engine.topo, mean, &candidates);
    let top1 = ranked[0].kind;

    let mut best = f64::INFINITY;
    let mut t_top1 = f64::NAN;
    for kind in &candidates {
        let t = run_alltoallv(&engine, kind, &sizes, false).unwrap().makespan;
        if *kind == top1 {
            t_top1 = t;
        }
        best = best.min(t);
    }
    assert!(t_top1.is_finite(), "model pick {} not in the sweep grid", top1.name());
    assert!(
        t_top1 <= best * 1.35,
        "skewed grid: selector picked {} at {t_top1:.6e}s, engine best is {best:.6e}s \
         ({:.1}% over the 35% budget)",
        top1.name(),
        100.0 * (t_top1 / best - 1.0)
    );
}
