//! Replay equivalence: the plan/replay executor must be **bit-identical**
//! to the threaded engine — zero tolerance — on makespans, per-phase
//! breakdowns, aggregate counters and schedule stats, across every
//! algorithm family, topology shape, distribution and machine profile
//! (including congestion-enabled ones, which exercise the burst/incast
//! factors).
//!
//! This is the contract that lets the coordinator, selector refinement
//! and figure harnesses substitute replay for thread-per-rank execution
//! on phantom workloads without changing a single recorded number.

use std::sync::Arc;

use tuna::algos::{
    compile_plan, hier, patch_plan, plan_for, run_alltoallv, run_alltoallv_replay,
    run_alltoallv_segmented, run_alltoallv_segmented_replay, segmented_plan_for, tuning,
    AlgoKind, ExecMode, GlobalAlgo, LocalAlgo, SegmentCompute,
};
use tuna::comm::replay::{self, ReplayError};
use tuna::comm::{CommPlan, Engine, EngineResult, FaultModel, FaultSpec, PlanBuilder, Topology};
use tuna::coordinator::{measure, RunConfig};
use tuna::model::MachineProfile;
use tuna::util::prop::forall;
use tuna::workload::{BlockSizes, Dist};

fn assert_identical(engine: &Engine, kind: &AlgoKind, sizes: &BlockSizes) {
    let threaded = run_alltoallv(engine, kind, sizes, false).expect("threaded run");
    let replayed = run_alltoallv_replay(engine, kind, sizes).expect("replay run");
    let name = kind.name();
    assert_eq!(
        threaded.makespan.to_bits(),
        replayed.makespan.to_bits(),
        "{name}: makespan {} (threaded) vs {} (replay)",
        threaded.makespan,
        replayed.makespan
    );
    assert_eq!(threaded.phases, replayed.phases, "{name}: phase breakdown");
    assert_eq!(threaded.counters, replayed.counters, "{name}: counters");
    assert_eq!(threaded.t_peak, replayed.t_peak, "{name}: t_peak");
    assert_eq!(threaded.rounds, replayed.rounds, "{name}: rounds");
    assert_eq!(threaded.algo, replayed.algo);
    assert!(replayed.validated);
}

fn engine(profile: MachineProfile, p: usize, q: usize) -> Engine {
    Engine::new(profile, Topology::new(p, q))
}

#[test]
fn every_family_bit_identical_on_fixed_grids() {
    for profile in [
        MachineProfile::test_flat(),
        MachineProfile::fugaku(),
        MachineProfile::polaris(),
    ] {
        for (p, q) in [(8usize, 2usize), (12, 4), (9, 3)] {
            let e = engine(profile.clone(), p, q);
            let sizes = BlockSizes::generate(p, Dist::Uniform { max: 512 }, p as u64);
            let mut kinds = vec![
                AlgoKind::SpreadOut,
                AlgoKind::OmpiLinear,
                AlgoKind::Pairwise,
                AlgoKind::Scattered { block_count: 3 },
                AlgoKind::Vendor,
                AlgoKind::Bruck2,
                AlgoKind::Tuna { radix: 2 },
                AlgoKind::Tuna { radix: p },
                AlgoKind::TunaAuto,
            ];
            if q >= 2 && p / q >= 2 {
                kinds.push(AlgoKind::hier_coalesced(2, 1));
                kinds.push(AlgoKind::hier_coalesced(q, 2));
                kinds.push(AlgoKind::hier_staggered(2, 5));
                kinds.push(AlgoKind::Hier {
                    local: LocalAlgo::Linear,
                    global: GlobalAlgo::Linear,
                });
                kinds.push(AlgoKind::Hier {
                    local: LocalAlgo::Tuna { radix: 2 },
                    global: GlobalAlgo::Bruck { radix: 2 },
                });
            }
            for kind in kinds {
                assert_identical(&e, &kind, &sizes);
            }
        }
    }
}

#[test]
fn skewed_and_degenerate_distributions_bit_identical() {
    // Zero-size blocks (power-law tails, FFT splits) and constant
    // uniform sizes must not perturb the plan.
    let e = engine(MachineProfile::fugaku(), 16, 4);
    for dist in [
        Dist::powerlaw_default(),
        Dist::normal_default(),
        Dist::FftN1,
        Dist::FftN2,
        Dist::Const { size: 64 },
        Dist::PowerLaw { max: 64, skew: 6.0 },
    ] {
        let sizes = BlockSizes::generate(16, dist, 5);
        for kind in [
            AlgoKind::Tuna { radix: 4 },
            AlgoKind::Pairwise,
            AlgoKind::hier_staggered(3, 2),
            AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 4 } },
        ] {
            assert_identical(&e, &kind, &sizes);
        }
    }
}

#[test]
fn local_global_compositions_bit_identical() {
    // The composition grid: every shipped local level crossed with every
    // shipped global level (including both legacy pairings via their
    // aliases), each bit-identical between threaded and replay
    // execution — the guarantee that lets the selector refine any
    // composition on the replay executor.
    let (p, q) = (12usize, 4usize);
    let n = p / q;
    let e = engine(MachineProfile::fugaku(), p, q);
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 384 }, 21);
    let locals = [LocalAlgo::Tuna { radix: 2 }, LocalAlgo::Tuna { radix: q }, LocalAlgo::Linear];
    let globals = [
        GlobalAlgo::Coalesced { block_count: 2 },
        GlobalAlgo::Staggered { block_count: 3 },
        GlobalAlgo::Linear,
        GlobalAlgo::Bruck { radix: 2 },
        GlobalAlgo::Bruck { radix: n },
    ];
    let mut compositions = 0;
    for local in locals {
        for global in globals {
            assert_identical(&e, &AlgoKind::Hier { local, global }, &sizes);
            compositions += 1;
        }
    }
    assert!(compositions >= 4, "grid must cover at least four compositions");
    for legacy in ["tuna-hier-coalesced:r=2,b=2", "tuna-hier-staggered:r=3,b=4"] {
        assert_identical(&e, &AlgoKind::parse(legacy).unwrap(), &sizes);
    }
}

#[test]
fn property_random_configs_all_families() {
    forall("replay == threaded", 30, |rng| {
        let q = 1 + rng.next_below(6) as usize; // 1..=6
        let n = 1 + rng.next_below(5) as usize; // 1..=5 nodes
        let p = (q * n).max(2);
        let q = if p % q == 0 { q } else { 1 };
        let profile = match rng.next_below(3) {
            0 => MachineProfile::test_flat(),
            1 => MachineProfile::fugaku(),
            _ => MachineProfile::polaris(),
        };
        let e = engine(profile, p, q);
        let dist = match rng.next_below(3) {
            0 => Dist::Uniform { max: 256 },
            1 => Dist::powerlaw_default(),
            _ => Dist::Const { size: 96 },
        };
        let sizes = BlockSizes::generate(p, dist, rng.next_u64());
        let kind = match rng.next_below(7) {
            0 => AlgoKind::SpreadOut,
            1 => AlgoKind::OmpiLinear,
            2 => AlgoKind::Pairwise,
            3 => AlgoKind::Scattered {
                block_count: 1 + rng.next_below(8) as usize,
            },
            4 => AlgoKind::TunaAuto,
            5 | 6 if q >= 2 && p / q >= 2 => hier::random_composition(rng, q, p / q),
            _ => AlgoKind::Tuna {
                radix: (2 + rng.next_below(p as u64) as usize).min(p),
            },
        };
        let threaded = run_alltoallv(&e, &kind, &sizes, false).map_err(|e| e.to_string())?;
        let replayed = run_alltoallv_replay(&e, &kind, &sizes).map_err(|e| e.to_string())?;
        if threaded.makespan.to_bits() != replayed.makespan.to_bits() {
            return Err(format!(
                "{} P={p} Q={q}: makespan {} != {}",
                kind.name(),
                threaded.makespan,
                replayed.makespan
            ));
        }
        if threaded.phases != replayed.phases || threaded.counters != replayed.counters {
            return Err(format!("{} P={p} Q={q}: phases/counters diverged", kind.name()));
        }
        if (threaded.t_peak, threaded.rounds) != (replayed.t_peak, replayed.rounds) {
            return Err(format!("{} P={p} Q={q}: stats diverged", kind.name()));
        }
        Ok(())
    });
}

#[test]
fn sparse_workloads_bit_identical_across_every_family() {
    // Structural sparsity switches every family onto its sparse
    // schedule (structural peers only); threaded and replay must stay
    // bit-identical there too, zero tolerance.
    for (p, q, nnz) in [(24usize, 4usize, 3usize), (64, 8, 6), (96, 8, 0), (128, 16, 16)] {
        let e = engine(MachineProfile::fugaku(), p, q);
        let sizes = BlockSizes::generate(p, Dist::Sparse { nnz, max: 512 }, p as u64);
        let n = p / q;
        let kinds = vec![
            AlgoKind::SpreadOut,
            AlgoKind::OmpiLinear,
            AlgoKind::Pairwise,
            AlgoKind::Scattered { block_count: 3 },
            AlgoKind::Vendor,
            AlgoKind::Bruck2,
            AlgoKind::Tuna { radix: 2 },
            AlgoKind::Tuna { radix: p },
            AlgoKind::TunaAuto,
            AlgoKind::hier_coalesced(2, 2),
            AlgoKind::hier_staggered(2, 5),
            AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Linear },
            AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Coalesced { block_count: 1 } },
            AlgoKind::Hier {
                local: LocalAlgo::Tuna { radix: 2 },
                global: GlobalAlgo::Bruck { radix: 2.min(n).max(2) },
            },
        ];
        for kind in kinds {
            assert_identical(&e, &kind, &sizes);
        }
    }
}

#[test]
fn sparse_bit_identity_holds_at_p512() {
    // The satellite bound: zero-tolerance threaded-vs-replay identity at
    // P = 512 on a sparse composed hierarchy and a sparse linear family.
    let (p, q) = (512usize, 32usize);
    let e = engine(MachineProfile::fugaku(), p, q);
    let sizes = BlockSizes::generate(p, Dist::Sparse { nnz: 8, max: 1024 }, 11);
    for kind in [
        AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap(),
        AlgoKind::SpreadOut,
    ] {
        assert_identical(&e, &kind, &sizes);
    }
}

#[test]
fn csr_workloads_with_empty_rows_bit_identical() {
    // Hand-built CSR patterns: empty send rows, zero entries dropped at
    // construction, self-only rows — every family round-trips them in
    // both modes without phantom sends.
    let p = 12;
    let mut rows: Vec<Vec<(usize, u64)>> = vec![Vec::new(); p];
    rows[0] = vec![(3, 64), (7, 8)];
    rows[1] = vec![(1, 16)]; // self only
    rows[2] = vec![(0, 0), (5, 24)]; // zero dropped
    rows[7] = (0..p).map(|d| (d, 8)).collect(); // full row
    // rows 3..=6 and 8..=11 send nothing at all.
    let sizes = BlockSizes::from_sparse_rows(p, rows);
    let e = engine(MachineProfile::test_flat(), p, 4);
    for kind in [
        AlgoKind::SpreadOut,
        AlgoKind::Pairwise,
        AlgoKind::Tuna { radix: 3 },
        AlgoKind::hier_staggered(2, 3),
        AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 3 } },
    ] {
        assert_identical(&e, &kind, &sizes);
    }
}

#[test]
fn property_random_sparse_configs_all_families() {
    forall("sparse replay == threaded", 25, |rng| {
        let q = 2 + rng.next_below(5) as usize; // 2..=6
        let n = 2 + rng.next_below(5) as usize; // 2..=6 nodes
        let p = q * n;
        let nnz = rng.next_below(p as u64 + 1) as usize;
        let sizes = BlockSizes::generate(p, Dist::Sparse { nnz, max: 256 }, rng.next_u64());
        let e = engine(MachineProfile::polaris(), p, q);
        let kind = match rng.next_below(6) {
            0 => AlgoKind::SpreadOut,
            1 => AlgoKind::Scattered { block_count: 1 + rng.next_below(6) as usize },
            2 => AlgoKind::TunaAuto,
            3 => AlgoKind::Tuna { radix: (2 + rng.next_below(p as u64) as usize).min(p) },
            _ => hier::random_composition(rng, q, n),
        };
        let threaded = run_alltoallv(&e, &kind, &sizes, false).map_err(|e| e.to_string())?;
        let replayed = run_alltoallv_replay(&e, &kind, &sizes).map_err(|e| e.to_string())?;
        if threaded.makespan.to_bits() != replayed.makespan.to_bits() {
            return Err(format!(
                "{} P={p} Q={q} nnz={nnz}: makespan {} != {}",
                kind.name(),
                threaded.makespan,
                replayed.makespan
            ));
        }
        if threaded.phases != replayed.phases || threaded.counters != replayed.counters {
            return Err(format!("{} P={p} nnz={nnz}: phases/counters diverged", kind.name()));
        }
        if (threaded.t_peak, threaded.rounds) != (replayed.t_peak, replayed.rounds) {
            return Err(format!("{} P={p} nnz={nnz}: stats diverged", kind.name()));
        }
        Ok(())
    });
}

#[test]
fn sparse_composed_hierarchy_scales_to_p8192() {
    // The satellite scale point: a sparse composed hierarchy at P = 8192
    // compiles a plan whose op count is proportional to the total
    // nonzeros (not P²) and replays exactly.
    let (p, q, nnz) = (8192usize, 64usize, 32usize);
    let e = engine(MachineProfile::fugaku(), p, q);
    let sizes = BlockSizes::generate(p, Dist::Sparse { nnz, max: 1024 }, 5);
    let kind = AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap();
    let plan = tuna::algos::plan_for(&e, &kind, &sizes).unwrap();
    let nnz_total = sizes.total_nnz();
    assert_eq!(nnz_total, (p * nnz) as u64, "sparse generator draws exactly nnz per row");
    assert!(
        (plan.total_ops() as u64) <= 64 * nnz_total,
        "plan {} ops not proportional to nnz ({})",
        plan.total_ops(),
        nnz_total
    );
    let rep = run_alltoallv_replay(&e, &kind, &sizes).unwrap();
    assert!(rep.makespan > 0.0 && rep.validated);
}

#[test]
fn sparse_replay_completes_at_p32768() {
    // The acceptance point: exact (plan/replay) execution at P = 32768
    // on a sparse workload — four times past the dense replay wall —
    // with the op-count proportionality asserted in-test.
    let (p, q, nnz) = (32768usize, 64usize, 16usize);
    let e = engine(MachineProfile::fugaku(), p, q);
    let sizes = BlockSizes::generate(p, Dist::Sparse { nnz, max: 1024 }, 9);
    let kind = AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap();
    let plan = tuna::algos::plan_for(&e, &kind, &sizes).unwrap();
    let nnz_total = sizes.total_nnz();
    assert!(
        (plan.total_ops() as u64) <= 64 * nnz_total,
        "plan {} ops not proportional to nnz ({})",
        plan.total_ops(),
        nnz_total
    );
    let rep = run_alltoallv_replay(&e, &kind, &sizes).unwrap();
    assert!(rep.makespan > 0.0 && rep.validated);
    // And the budgeted coordinator path picks exact replay here.
    let cfg = RunConfig {
        p,
        q,
        dist: Dist::Sparse { nnz, max: 1024 },
        iters: 1,
        ..RunConfig::default()
    };
    assert_eq!(
        tuna::coordinator::choose_fidelity(&kind, p, &cfg).name(),
        "replay"
    );
}

#[test]
fn tuna_auto_with_tuning_table_resolves_identically() {
    // A table-backed tuna:auto must compile the same radix the threaded
    // dispatch agrees on — exercised by pointing the table at a radix
    // the heuristic would never pick (mirrors the dispatch unit test).
    let (p, q) = (12usize, 4usize);
    let profile = MachineProfile::test_flat();
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 64 }, 3);
    let total: u64 = (0..p).map(|s| sizes.row(s).iter().sum::<u64>()).sum();
    let mean = total as f64 / (p * p) as f64;
    let heur = tuning::heuristic_radix(p, mean);
    let table_radix = 5usize;
    assert_ne!(heur, table_radix);

    let table = tuning::TuningTable {
        entries: vec![tuning::TuningEntry {
            machine: profile.name.to_string(),
            p,
            q,
            dist: "uniform".into(),
            mean_block: mean,
            rank: 1,
            algo: AlgoKind::Tuna { radix: table_radix },
            model_time: 1e-3,
            measured_time: None,
        }],
    };

    let plain = engine(profile.clone(), p, q);
    let tuned = Engine::new(profile, Topology::new(p, q)).with_tuning(Some(Arc::new(table)));
    assert_identical(&plain, &AlgoKind::TunaAuto, &sizes);
    assert_identical(&tuned, &AlgoKind::TunaAuto, &sizes);
    // And the tuned replay really used the table radix.
    let tuned_replay = run_alltoallv_replay(&tuned, &AlgoKind::TunaAuto, &sizes).unwrap();
    let fixed_kind = AlgoKind::Tuna { radix: table_radix };
    let fixed = run_alltoallv_replay(&plain, &fixed_kind, &sizes).unwrap();
    assert_eq!(tuned_replay.rounds, fixed.rounds);
    let plain_replay = run_alltoallv_replay(&plain, &AlgoKind::TunaAuto, &sizes).unwrap();
    assert_ne!(tuned_replay.rounds, plain_replay.rounds);
}

#[test]
fn cached_replays_are_stable() {
    // Repeated replays of one collective hit the plan cache and keep
    // producing the identical report.
    let e = engine(MachineProfile::fugaku(), 32, 8);
    let sizes = BlockSizes::generate(32, Dist::Uniform { max: 1024 }, 11);
    let kind = AlgoKind::hier_coalesced(4, 2);
    let first = run_alltoallv_replay(&e, &kind, &sizes).unwrap();
    for _ in 0..3 {
        let again = run_alltoallv_replay(&e, &kind, &sizes).unwrap();
        assert_eq!(first.makespan.to_bits(), again.makespan.to_bits());
        assert_eq!(first.counters, again.counters);
    }
    let (hits, misses) = e.plan_cache.stats();
    assert_eq!((hits, misses), (3, 1));
}

fn assert_results_identical(a: &EngineResult<()>, b: &EngineResult<()>, ctx: &str) {
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{ctx}: makespan {} vs {}",
        a.makespan,
        b.makespan
    );
    assert_eq!(a.ranks.len(), b.ranks.len(), "{ctx}: rank count");
    for (x, y) in a.ranks.iter().zip(b.ranks.iter()) {
        assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "{ctx}: rank {} finish", x.rank);
        assert_eq!(x.phases, y.phases, "{ctx}: rank {} phases", x.rank);
        assert_eq!(x.counters, y.counters, "{ctx}: rank {} counters", x.rank);
    }
}

/// The tentpole contract: sharded replay is bit-identical to the
/// single-threaded executor for every shard count, across all algorithm
/// families (legacy-alias hier specs included), dense and sparse.
#[test]
fn shard_count_independence_across_all_families() {
    let dense_kinds = |p: usize, q: usize| {
        let mut kinds = vec![
            AlgoKind::SpreadOut,
            AlgoKind::OmpiLinear,
            AlgoKind::Pairwise,
            AlgoKind::Scattered { block_count: 3 },
            AlgoKind::Vendor,
            AlgoKind::Bruck2,
            AlgoKind::Tuna { radix: 2 },
            AlgoKind::Tuna { radix: p },
            AlgoKind::TunaAuto,
        ];
        if q >= 2 && p / q >= 2 {
            kinds.push(AlgoKind::hier_coalesced(2, 2));
            kinds.push(AlgoKind::hier_staggered(2, 3));
            kinds.push(AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Linear });
            kinds.push(AlgoKind::parse("tuna-hier-coalesced:r=2,b=2").unwrap());
            kinds.push(AlgoKind::parse("tuna-hier-staggered:r=3,b=4").unwrap());
        }
        kinds
    };
    let cases = [
        (12usize, 4usize, Dist::Uniform { max: 512 }),
        (16, 4, Dist::powerlaw_default()),
        (64, 8, Dist::Sparse { nnz: 6, max: 512 }),
        (24, 4, Dist::Sparse { nnz: 3, max: 256 }),
    ];
    for (p, q, dist) in cases {
        let e = engine(MachineProfile::fugaku(), p, q);
        let sizes = BlockSizes::generate(p, dist, p as u64);
        for kind in dense_kinds(p, q) {
            let plan = plan_for(&e, &kind, &sizes).unwrap();
            let single = replay::execute_sharded(&e.profile, e.topo, &plan, 1).unwrap();
            for shards in [2usize, 4, 8] {
                let sharded = replay::execute_sharded(&e.profile, e.topo, &plan, shards).unwrap();
                assert_results_identical(
                    &single,
                    &sharded,
                    &format!("{} P={p} Q={q} shards={shards}", kind.name()),
                );
            }
        }
    }
}

#[test]
fn property_random_shard_counts_bit_identical() {
    forall("sharded replay == single-threaded replay", 20, |rng| {
        let q = 1 + rng.next_below(6) as usize;
        let n = 1 + rng.next_below(5) as usize;
        let p = (q * n).max(2);
        let q = if p % q == 0 { q } else { 1 };
        let sparse = rng.next_below(2) == 0;
        let dist = if sparse {
            Dist::Sparse { nnz: rng.next_below(p as u64 + 1) as usize, max: 256 }
        } else {
            Dist::Uniform { max: 256 }
        };
        let sizes = BlockSizes::generate(p, dist, rng.next_u64());
        let e = engine(MachineProfile::polaris(), p, q);
        let kind = match rng.next_below(5) {
            0 => AlgoKind::SpreadOut,
            1 => AlgoKind::Pairwise,
            2 => AlgoKind::TunaAuto,
            3 if q >= 2 && p / q >= 2 => hier::random_composition(rng, q, p / q),
            _ => AlgoKind::Tuna { radix: (2 + rng.next_below(p as u64) as usize).min(p) },
        };
        let plan = plan_for(&e, &kind, &sizes).map_err(|e| e.to_string())?;
        let single =
            replay::execute_sharded(&e.profile, e.topo, &plan, 1).map_err(|e| e.to_string())?;
        let shards = 1 + rng.next_below(10) as usize;
        let sharded = replay::execute_sharded(&e.profile, e.topo, &plan, shards)
            .map_err(|e| e.to_string())?;
        if single.makespan.to_bits() != sharded.makespan.to_bits() {
            return Err(format!(
                "{} P={p} shards={shards}: makespan {} != {}",
                kind.name(),
                single.makespan,
                sharded.makespan
            ));
        }
        for (x, y) in single.ranks.iter().zip(sharded.ranks.iter()) {
            if x.finish.to_bits() != y.finish.to_bits()
                || x.phases != y.phases
                || x.counters != y.counters
            {
                return Err(format!(
                    "{} P={p} shards={shards}: rank {} diverged",
                    kind.name(),
                    x.rank
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn replay_shards_knob_preserves_identity_end_to_end() {
    // The engine-level knob: a pinned shard count flows through
    // `run_alltoallv_replay` and stays bit-identical to the threaded
    // engine and to the serial replay.
    let (p, q) = (64usize, 8usize);
    let sizes = BlockSizes::generate(p, Dist::Sparse { nnz: 8, max: 512 }, 13);
    let kind = AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap();
    let sharded_engine =
        Engine::new(MachineProfile::fugaku(), Topology::new(p, q)).with_replay_shards(Some(4));
    assert_identical(&sharded_engine, &kind, &sizes);
    let serial_engine =
        Engine::new(MachineProfile::fugaku(), Topology::new(p, q)).with_replay_shards(Some(1));
    let a = run_alltoallv_replay(&serial_engine, &kind, &sizes).unwrap();
    let b = run_alltoallv_replay(&sharded_engine, &kind, &sizes).unwrap();
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.phases, b.phases);
    assert_eq!(a.counters, b.counters);
}

fn two_rank_plan(r0: PlanBuilder, r1: PlanBuilder) -> CommPlan {
    CommPlan::from_rank_plans(2, 1, "hand-built".into(), vec![r0.finish(), r1.finish()], 0, 0)
}

/// The hardening satellites: broken plans surface typed errors, never
/// panics, identically on the serial and sharded paths.
#[test]
fn broken_plans_surface_typed_errors_not_panics() {
    let profile = MachineProfile::test_flat();
    let topo = Topology::flat(2);

    // A Wait whose message is never sent: typed deadlock with the
    // parked rank's program position.
    let mut b0 = PlanBuilder::new(0, 2);
    b0.recv(1, 1);
    b0.wait();
    let deadlocked = two_rank_plan(b0, PlanBuilder::new(1, 2));
    for shards in [1usize, 2] {
        let err = replay::execute_sharded(&profile, topo, &deadlocked, shards).unwrap_err();
        assert_eq!(
            err,
            ReplayError::PlanDeadlock {
                rank: 0,
                pc: 1,
                ops: 2,
                algo: "hand-built".into(),
                missing: 1,
            },
            "shards={shards}"
        );
        assert!(err.to_string().contains("replay deadlock"));
    }

    // A send nobody receives: typed undrained-mailbox report.
    let mut b0 = PlanBuilder::new(0, 2);
    b0.send(1, 9, 8);
    b0.wait();
    let undrained = two_rank_plan(b0, PlanBuilder::new(1, 2));
    for shards in [1usize, 2] {
        let err = replay::execute_sharded(&profile, topo, &undrained, shards).unwrap_err();
        assert_eq!(
            err,
            ReplayError::UndrainedMailbox { rank: 1, messages: 1, channels: 1 },
            "shards={shards}"
        );
        assert!(err.to_string().contains("not drained"));
    }

    // A plan executed against the wrong topology: typed shape mismatch
    // (the PR 4 `Topology::try_new` precedent, now on the replay path).
    let shaped = two_rank_plan(PlanBuilder::new(0, 2), PlanBuilder::new(1, 2));
    let err = replay::execute(&profile, Topology::flat(4), &shaped).unwrap_err();
    assert_eq!(
        err,
        ReplayError::ShapeMismatch { plan_p: 2, plan_q: 1, topo_p: 4, topo_q: 1 }
    );
    // And it converts into the crate error type callers surface.
    let typed: tuna::TunaError = err.into();
    assert!(typed.to_string().contains("configuration"), "{typed}");
}

/// The incremental-patching half of the tentpole: a patched plan is
/// op-for-op identical to a fresh compile, lands in the cache under the
/// new workload's key, and replays bit-identically.
#[test]
fn patched_plans_equal_fresh_compilation_op_for_op() {
    let (p, q) = (12usize, 4usize);
    let e = engine(MachineProfile::fugaku(), p, q);
    let gen = BlockSizes::generate(p, Dist::Uniform { max: 256 }, 7);
    let base = BlockSizes::from_dense((0..p).map(|r| gen.row(r)).collect());
    let kinds = [
        AlgoKind::SpreadOut,
        AlgoKind::OmpiLinear,
        AlgoKind::Pairwise,
        AlgoKind::Scattered { block_count: 3 },
        AlgoKind::Vendor,
    ];
    for kind in kinds {
        let base_plan = plan_for(&e, &kind, &base).unwrap();
        let new = base
            .replace_dense_row(2, vec![64; p])
            .replace_dense_row(5, (0..p as u64).map(|d| d * 8).collect());
        let patched = patch_plan(&e, &kind, &base, &base_plan, &new)
            .expect("linear dense plans must be patchable");
        let fresh = compile_plan(&e, &kind, &new).unwrap();
        assert_eq!(*patched, fresh, "{}: patched != fresh compile", kind.name());
        // The patched plan is cached under the new workload's key.
        let cached = plan_for(&e, &kind, &new).unwrap();
        assert!(Arc::ptr_eq(&patched, &cached), "{}: cache miss after patch", kind.name());
        // And the replayed report still matches the threaded engine.
        assert_identical(&e, &kind, &new);
    }
}

#[test]
fn sparse_patching_requires_stable_structure() {
    let (p, q) = (24usize, 4usize);
    let e = engine(MachineProfile::fugaku(), p, q);
    let base = BlockSizes::generate(p, Dist::Sparse { nnz: 4, max: 256 }, 3);
    let kind = AlgoKind::Scattered { block_count: 2 };
    let base_plan = plan_for(&e, &kind, &base).unwrap();

    // Size-only change on one row (same destination set): patchable and
    // equal to a fresh compile, op for op.
    let row7: Vec<(usize, u64)> = base.row_view(7).entries().map(|(d, s)| (d, s * 2)).collect();
    let resized = base.replace_sparse_row(7, row7);
    let patched = patch_plan(&e, &kind, &base, &base_plan, &resized)
        .expect("size-only sparse change must patch");
    let fresh = compile_plan(&e, &kind, &resized).unwrap();
    assert_eq!(*patched, fresh);
    assert_identical(&e, &kind, &resized);

    // Structural change (a destination added): receivers' schedules
    // would shift, so patching must refuse.
    let mut grown: Vec<(usize, u64)> = base.row_view(7).entries().collect();
    let absent = (0..p).find(|&d| !base.row_view(7).contains(d)).unwrap();
    grown.push((absent, 8));
    let restructured = base.replace_sparse_row(7, grown);
    assert_eq!(patch_plan(&e, &kind, &base, &base_plan, &restructured), None);

    // Globally coupled families are never patchable.
    let tuna_plan = plan_for(&e, &AlgoKind::Tuna { radix: 4 }, &base).unwrap();
    assert_eq!(
        patch_plan(&e, &AlgoKind::Tuna { radix: 4 }, &base, &tuna_plan, &resized),
        None
    );

    // Identical generator descriptors: the O(1) empty diff returns the
    // base plan itself.
    let same = BlockSizes::generate(p, Dist::Sparse { nnz: 4, max: 256 }, 3);
    let unchanged = patch_plan(&e, &kind, &base, &base_plan, &same).unwrap();
    assert!(Arc::ptr_eq(&unchanged, &base_plan));
}

/// Fault specs valid on every grid below (rank targets < 12, node
/// targets < 3): one spec per clause kind plus a combined spec, covering
/// every perturbation path the clocks implement.
fn fault_specs() -> Vec<FaultSpec> {
    [
        "straggler:rank=1,slow=4",
        "link:node=0-1,bw=0.25,lat=2",
        "jitter:sigma=0.2,seed=7",
        "outage:node=0,from=0.0001,until=0.0002",
        "straggler:rank=3,slow=2/link:node=0-2,bw=0.5,lat=1.5/jitter:sigma=0.1,seed=9/outage:node=1,from=0.00005,until=0.00015",
    ]
    .iter()
    .map(|s| FaultSpec::parse(s).expect("grid specs parse"))
    .collect()
}

/// The PR 8 tentpole contract: fault perturbations are a pure function
/// of `(seed, rank, peer, event index)`, so threaded and replay
/// execution stay bit-identical under any fault spec — and the sharded
/// replay stays bit-identical at every shard count.
#[test]
fn faulted_runs_bit_identical_across_executors_and_shard_counts() {
    let cases = [
        (12usize, 4usize, Dist::Uniform { max: 512 }),
        (12, 3, Dist::powerlaw_default()),
        (24, 4, Dist::Sparse { nnz: 3, max: 256 }),
    ];
    let kinds = |p: usize, q: usize| {
        let mut kinds = vec![
            AlgoKind::SpreadOut,
            AlgoKind::OmpiLinear,
            AlgoKind::Pairwise,
            AlgoKind::Scattered { block_count: 3 },
            AlgoKind::Vendor,
            AlgoKind::Bruck2,
            AlgoKind::Tuna { radix: 2 },
            AlgoKind::TunaAuto,
        ];
        if q >= 2 && p / q >= 2 {
            kinds.push(AlgoKind::hier_coalesced(2, 2));
            kinds.push(AlgoKind::hier_staggered(2, 3));
            kinds.push(AlgoKind::Hier {
                local: LocalAlgo::Linear,
                global: GlobalAlgo::Bruck { radix: 2 },
            });
        }
        kinds
    };
    for (p, q, dist) in cases {
        let sizes = BlockSizes::generate(p, dist, p as u64);
        for spec in fault_specs() {
            let e = Engine::new(MachineProfile::fugaku(), Topology::new(p, q)).with_faults(&spec);
            let model = FaultModel::compile(&spec, q);
            for kind in kinds(p, q) {
                // Threaded (rank threads, faulted clocks) vs replay
                // (event loop, same lenses): zero tolerance.
                assert_identical(&e, &kind, &sizes);
                // Shard-count independence under the same fault model.
                let plan = plan_for(&e, &kind, &sizes).unwrap();
                let single =
                    replay::execute_faulted(&e.profile, e.topo, &plan, 1, Some(&model)).unwrap();
                for shards in [2usize, 4, 8] {
                    let sharded =
                        replay::execute_faulted(&e.profile, e.topo, &plan, shards, Some(&model))
                            .unwrap();
                    assert_results_identical(
                        &single,
                        &sharded,
                        &format!(
                            "{} P={p} Q={q} shards={shards} faults={}",
                            kind.name(),
                            spec.spec()
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn faulted_runs_actually_differ_from_healthy_ones() {
    // The identity above must not hold vacuously: a non-empty spec with
    // real targets changes the makespan.
    let (p, q) = (12usize, 4usize);
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 512 }, 3);
    let healthy = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let spec = FaultSpec::parse("straggler:rank=1,slow=4").unwrap();
    let faulted = Engine::new(MachineProfile::fugaku(), Topology::new(p, q)).with_faults(&spec);
    for kind in [AlgoKind::SpreadOut, AlgoKind::Tuna { radix: 2 }] {
        let h = run_alltoallv_replay(&healthy, &kind, &sizes).unwrap();
        let f = run_alltoallv_replay(&faulted, &kind, &sizes).unwrap();
        assert!(
            f.makespan > h.makespan,
            "{}: faulted {} not slower than healthy {}",
            kind.name(),
            f.makespan,
            h.makespan
        );
    }
}

#[test]
fn empty_fault_spec_is_provably_zero_perturbation() {
    // The acceptance criterion: an empty spec leaves every recorded
    // number bit-identical to a run with no fault plumbing at all — on
    // the engine (empty specs compile to no model) and on the replay
    // executor even when an explicit empty model is installed, whose
    // identity lenses multiply every cost by exactly 1.0.
    let (p, q) = (12usize, 4usize);
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 512 }, 3);
    let plain = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let empty = Engine::new(MachineProfile::fugaku(), Topology::new(p, q))
        .with_faults(&FaultSpec::default());
    let empty_model = FaultModel::compile(&FaultSpec::default(), q);
    for kind in [
        AlgoKind::SpreadOut,
        AlgoKind::Tuna { radix: 2 },
        AlgoKind::hier_coalesced(2, 2),
    ] {
        let a = run_alltoallv(&plain, &kind, &sizes, false).unwrap();
        let b = run_alltoallv(&empty, &kind, &sizes, false).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{}", kind.name());
        assert_eq!(a.phases, b.phases);
        assert_eq!(a.counters, b.counters);
        let plan = plan_for(&plain, &kind, &sizes).unwrap();
        let bare = replay::execute(&plain.profile, plain.topo, &plan).unwrap();
        let lensed =
            replay::execute_faulted(&plain.profile, plain.topo, &plan, 2, Some(&empty_model))
                .unwrap();
        assert_results_identical(&bare, &lensed, &format!("{} empty-model lens", kind.name()));
    }
}

#[test]
fn measure_replay_extends_past_thread_budget() {
    // A P above the threaded budgets but inside the replay budget runs
    // at exact fidelity — the large-P point thread-per-rank never
    // attempted at these budgets.
    let cfg = RunConfig {
        p: 256,
        q: 32,
        dist: Dist::Uniform { max: 128 },
        iters: 2,
        engine_limit_linear: 16,
        engine_limit_log: 64,
        engine_limit_replay: 512,
        ..RunConfig::default()
    };
    let m = measure(&cfg, &AlgoKind::Tuna { radix: 4 }).unwrap();
    assert_eq!(m.fidelity.name(), "replay");
    assert!(m.median() > 0.0);
    // Same point with replay disabled falls back to the model.
    let threaded_only = RunConfig {
        mode: ExecMode::Threaded,
        ..cfg
    };
    let m2 = measure(&threaded_only, &AlgoKind::Tuna { radix: 4 }).unwrap();
    assert_eq!(m2.fidelity.name(), "model");
}

fn assert_reports_identical(
    a: &tuna::algos::RunReport,
    b: &tuna::algos::RunReport,
    ctx: &str,
) {
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{ctx}: makespan {} vs {}",
        a.makespan,
        b.makespan
    );
    assert_eq!(a.phases, b.phases, "{ctx}: phase breakdown");
    assert_eq!(a.counters, b.counters, "{ctx}: counters");
    assert_eq!(a.t_peak, b.t_peak, "{ctx}: t_peak");
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.algo, b.algo, "{ctx}: algo name");
}

/// The PR 9 baseline contract: `segments=1` with no compute is the
/// unsegmented run — bit-identical reports out of the segmented driver
/// on BOTH executors, against the plain threaded engine, across every
/// family, dense and sparse, and under every tested shard count.
#[test]
fn segments_one_bit_identical_to_unsegmented() {
    let kinds = |p: usize, q: usize| {
        let mut kinds = vec![
            AlgoKind::SpreadOut,
            AlgoKind::OmpiLinear,
            AlgoKind::Pairwise,
            AlgoKind::Scattered { block_count: 3 },
            AlgoKind::Vendor,
            AlgoKind::Bruck2,
            AlgoKind::Tuna { radix: 2 },
            AlgoKind::TunaAuto,
        ];
        if q >= 2 && p / q >= 2 {
            kinds.push(AlgoKind::hier_coalesced(2, 2));
            kinds.push(AlgoKind::hier_staggered(2, 3));
            kinds.push(AlgoKind::Hier {
                local: LocalAlgo::Tuna { radix: 2 },
                global: GlobalAlgo::Bruck { radix: 2 },
            });
        }
        kinds
    };
    let cases = [
        (12usize, 4usize, Dist::Uniform { max: 512 }),
        (16, 4, Dist::powerlaw_default()),
        (24, 4, Dist::Sparse { nnz: 3, max: 256 }),
    ];
    for (p, q, dist) in cases {
        let e = engine(MachineProfile::fugaku(), p, q);
        let sizes = BlockSizes::generate(p, dist, p as u64);
        for kind in kinds(p, q) {
            let ctx = format!("{} P={p} Q={q} segments=1", kind.name());
            let unseg = run_alltoallv(&e, &kind, &sizes, false).unwrap();
            let seg_threaded =
                run_alltoallv_segmented(&e, &kind, &sizes, 1, false, &SegmentCompute::None)
                    .unwrap();
            let seg_replay =
                run_alltoallv_segmented_replay(&e, &kind, &sizes, 1, false, &SegmentCompute::None)
                    .unwrap();
            assert_reports_identical(&unseg, &seg_threaded, &format!("{ctx} threaded"));
            assert_reports_identical(&unseg, &seg_replay, &format!("{ctx} replay"));
            // Shard-count independence of the K=1 stitched plan.
            let plan =
                segmented_plan_for(&e, &kind, &sizes, 1, false, &SegmentCompute::None).unwrap();
            let single = replay::execute_sharded(&e.profile, e.topo, &plan, 1).unwrap();
            for shards in [2usize, 4, 8] {
                let sharded =
                    replay::execute_sharded(&e.profile, e.topo, &plan, shards).unwrap();
                assert_results_identical(&single, &sharded, &format!("{ctx} shards={shards}"));
            }
        }
    }
}

/// The PR 9 tentpole contract: segmented runs — every tested K, both
/// stitches, with and without per-segment compute — stay bit-identical
/// between the threaded engine and the sharded replay executor, under
/// every tested shard count, and the exposure counters partition the
/// comm window exactly (`exposed + hidden == window`, zero tolerance).
#[test]
fn segmented_runs_bit_identical_across_executors_and_shard_counts() {
    let cases = [
        (12usize, 4usize, Dist::Uniform { max: 512 }),
        (24, 4, Dist::Sparse { nnz: 3, max: 256 }),
    ];
    let kinds = [
        AlgoKind::SpreadOut,
        AlgoKind::Pairwise,
        AlgoKind::Tuna { radix: 2 },
        AlgoKind::hier_coalesced(2, 2),
        AlgoKind::Hier {
            local: LocalAlgo::Tuna { radix: 2 },
            global: GlobalAlgo::Bruck { radix: 2 },
        },
    ];
    for (p, q, dist) in cases {
        let e = engine(MachineProfile::fugaku(), p, q);
        let sizes = BlockSizes::generate(p, dist, p as u64);
        for kind in &kinds {
            for segments in [2usize, 4] {
                for overlap in [false, true] {
                    for compute in [SegmentCompute::None, SegmentCompute::Uniform(2e-5)] {
                        let ctx = format!(
                            "{} P={p} Q={q} K={segments} overlap={overlap}",
                            kind.name()
                        );
                        let threaded = run_alltoallv_segmented(
                            &e, kind, &sizes, segments, overlap, &compute,
                        )
                        .unwrap();
                        let replayed = run_alltoallv_segmented_replay(
                            &e, kind, &sizes, segments, overlap, &compute,
                        )
                        .unwrap();
                        assert_reports_identical(&threaded, &replayed, &ctx);
                        // exposed + hidden partition the total comm
                        // window exactly — the identity the overlap
                        // columns and overlap_speedup rows rest on.
                        let c = threaded.counters;
                        assert_eq!(
                            (c.exposed_comm + c.hidden_comm).to_bits(),
                            c.comm_window().to_bits(),
                            "{ctx}: exposure partition"
                        );
                        assert!(c.comm_window() > 0.0, "{ctx}: empty comm window");
                        // Shard-count independence of the stitched plan.
                        let plan =
                            segmented_plan_for(&e, kind, &sizes, segments, overlap, &compute)
                                .unwrap();
                        let single =
                            replay::execute_sharded(&e.profile, e.topo, &plan, 1).unwrap();
                        for shards in [2usize, 4, 8] {
                            let sharded =
                                replay::execute_sharded(&e.profile, e.topo, &plan, shards)
                                    .unwrap();
                            assert_results_identical(
                                &single,
                                &sharded,
                                &format!("{ctx} shards={shards}"),
                            );
                        }
                    }
                }
            }
        }
    }
}

/// The hiding the tentpole exists to deliver, measured end to end: with
/// real per-segment compute, the pipelined stitch exposes strictly less
/// communication than the blocking stitch and hides strictly more —
/// while moving exactly the same bytes.
#[test]
fn pipelined_stitch_hides_comm_the_blocking_stitch_exposes() {
    let (p, q, segments) = (16usize, 4usize, 4usize);
    let e = engine(MachineProfile::fugaku(), p, q);
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 4096 }, 7);
    for kind in [AlgoKind::SpreadOut, AlgoKind::Tuna { radix: 4 }] {
        // Size the per-segment compute off the blocking probe so the
        // pipeline has something real to hide at any profile scale.
        let probe =
            run_alltoallv_segmented_replay(&e, &kind, &sizes, segments, false, &SegmentCompute::None)
                .unwrap();
        let per_seg = SegmentCompute::Uniform(probe.makespan / segments as f64);
        let blocking =
            run_alltoallv_segmented_replay(&e, &kind, &sizes, segments, false, &per_seg).unwrap();
        let pipelined =
            run_alltoallv_segmented_replay(&e, &kind, &sizes, segments, true, &per_seg).unwrap();
        let name = kind.name();
        assert!(
            pipelined.counters.exposed_comm < blocking.counters.exposed_comm,
            "{name}: pipelined exposed {} not below blocking {}",
            pipelined.counters.exposed_comm,
            blocking.counters.exposed_comm
        );
        assert!(
            pipelined.counters.hidden_comm > blocking.counters.hidden_comm,
            "{name}: pipelined hid {} vs blocking {}",
            pipelined.counters.hidden_comm,
            blocking.counters.hidden_comm
        );
        assert!(
            pipelined.makespan <= blocking.makespan,
            "{name}: pipelined {} slower than blocking {}",
            pipelined.makespan,
            blocking.makespan
        );
        assert_eq!(
            pipelined.counters.total_bytes(),
            blocking.counters.total_bytes(),
            "{name}: stitches moved different byte totals"
        );
    }
}
