//! End-to-end error-path contract for the `tuna` CLI.
//!
//! Every failure a user can trigger from the command line must surface as
//! a typed `error: ...` message on stderr with a nonzero exit code —
//! never a panic, never a zero exit with garbage output. The replay
//! executor's `ReplayError` variants are not reachable from well-formed
//! CLI inputs (the coordinator compiles plans and topologies that match
//! by construction), so the hidden `tuna debug-errors case=<name>`
//! maintenance arm hand-builds each broken input in-process and feeds it
//! through the real `main` error path.

use std::process::{Command, Output};

fn tuna(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_tuna"))
        .args(args)
        .output()
        .expect("spawn tuna binary")
}

/// Assert a failing invocation dies cleanly: nonzero exit, a typed
/// `error: ` line containing `fragment`, and no panic anywhere.
fn assert_typed_error(args: &[&str], fragment: &str) {
    let out = tuna(args);
    let stderr = String::from_utf8_lossy(&out.stderr);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        !out.status.success(),
        "`tuna {}` unexpectedly succeeded\nstdout: {stdout}",
        args.join(" ")
    );
    assert_eq!(
        out.status.code(),
        Some(1),
        "`tuna {}` should exit 1 (a panic exits 101)\nstderr: {stderr}",
        args.join(" ")
    );
    assert!(
        stderr.starts_with("error: "),
        "`tuna {}` stderr must start with `error: `\nstderr: {stderr}",
        args.join(" ")
    );
    assert!(
        stderr.contains(fragment),
        "`tuna {}` stderr missing `{fragment}`\nstderr: {stderr}",
        args.join(" ")
    );
    for s in [&stderr, &stdout] {
        assert!(
            !s.contains("panicked"),
            "`tuna {}` panicked\noutput: {s}",
            args.join(" ")
        );
    }
}

#[test]
fn unknown_command_is_a_typed_error() {
    assert_typed_error(&["frobnicate"], "unknown command");
}

#[test]
fn unknown_config_key_is_a_typed_error() {
    assert_typed_error(&["run", "algo=tuna:r=2", "bogus=1"], "unknown config key");
}

#[test]
fn bad_topology_is_a_typed_error() {
    assert_typed_error(
        &["run", "algo=tuna:r=2", "p=10", "q=4"],
        "must divide",
    );
}

#[test]
fn replay_with_real_payloads_is_a_typed_contradiction() {
    assert_typed_error(
        &["run", "algo=tuna:r=2", "p=8", "q=2", "mode=replay", "real=true"],
        "phantom-only",
    );
}

#[test]
fn malformed_fault_spec_is_a_typed_error() {
    assert_typed_error(
        &["run", "algo=tuna:r=2", "p=8", "q=2", "faults=bogus"],
        "faults",
    );
}

#[test]
fn out_of_range_fault_target_is_a_typed_error() {
    assert_typed_error(
        &["run", "algo=tuna:r=2", "p=8", "q=2", "faults=straggler:rank=99,slow=2"],
        "rank",
    );
}

#[test]
fn serve_rejects_bad_degradation_knobs_with_typed_errors() {
    assert_typed_error(&["serve", "--quick", "deadline=-1"], "deadline");
    assert_typed_error(&["serve", "--quick", "retries=2"], "retries");
}

#[test]
fn segmented_overlap_knobs_reject_contradictions_with_typed_errors() {
    // segments=0 names no collective at all.
    assert_typed_error(
        &["run", "algo=tuna:r=2", "p=8", "q=2", "segments=0"],
        "segments must be >= 1",
    );
    // overlap=true with nothing to pipeline against.
    assert_typed_error(
        &["run", "algo=tuna:r=2", "p=8", "q=2", "overlap=true"],
        "requires segments >= 2",
    );
    assert_typed_error(
        &["run", "algo=tuna:r=2", "p=8", "q=2", "segments=1", "overlap=true"],
        "requires segments >= 2",
    );
    // Segmented plans model byte ranges; real payload buffers can't be
    // split along them.
    assert_typed_error(
        &["run", "algo=tuna:r=2", "p=8", "q=2", "segments=2", "real=true"],
        "phantom-only",
    );
    // A persistent handle freezes one plan; the stitcher makes K.
    assert_typed_error(
        &["run", "algo=tuna:r=2", "p=8", "q=2", "segments=2", "persistent=true"],
        "does not compose with segments",
    );
}

#[test]
fn segmented_run_succeeds_and_reports_exposure() {
    // The happy path behind the error wall: a segmented overlap run
    // exits 0 and prints the measured exposed/hidden split.
    let out = tuna(&[
        "run",
        "algo=tuna:r=2",
        "p=8",
        "q=2",
        "dist=uniform:256",
        "iters=1",
        "mode=replay",
        "segments=4",
        "overlap=true",
        "compute=0.00001",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "segmented run failed\nstdout: {stdout}\nstderr: {stderr}"
    );
    assert!(stdout.contains("median"), "no measurement printed: {stdout}");
    assert!(
        stdout.contains("exposed") && stdout.contains("hidden"),
        "no exposure report printed: {stdout}"
    );
}

// Every `ReplayError` variant, plus the persistent stale-counts error,
// through the real `error: {e}` / exit-1 path.

#[test]
fn replay_shape_mismatch_surfaces_through_the_cli() {
    assert_typed_error(
        &["debug-errors", "case=shape-mismatch"],
        "plan/topology mismatch",
    );
}

#[test]
fn replay_deadlock_surfaces_through_the_cli() {
    assert_typed_error(&["debug-errors", "case=plan-deadlock"], "replay deadlock");
}

#[test]
fn undrained_mailbox_surfaces_through_the_cli() {
    assert_typed_error(&["debug-errors", "case=undrained"], "not drained");
}

#[test]
fn persistent_stale_counts_surfaces_through_the_cli() {
    assert_typed_error(&["debug-errors", "case=stale-counts"], "frozen at init");
}

#[test]
fn debug_errors_rejects_unknown_or_missing_cases() {
    assert_typed_error(&["debug-errors", "case=nonsense"], "unknown debug-errors case");
    assert_typed_error(&["debug-errors"], "usage: tuna debug-errors");
}

#[test]
fn faulted_run_still_succeeds_end_to_end() {
    // The fault path itself is not an error path: a well-formed spec on a
    // tiny run exits 0 and prints a measurement.
    let out = tuna(&[
        "run",
        "algo=spread-out",
        "p=4",
        "q=2",
        "dist=uniform:64",
        "iters=1",
        "faults=straggler:rank=1,slow=2",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "faulted run failed\nstdout: {stdout}\nstderr: {stderr}"
    );
    assert!(stdout.contains("median"), "no measurement printed: {stdout}");
}
