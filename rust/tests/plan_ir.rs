//! Plan-IR properties (PR 10): the compact interned arena must be a
//! **lossless** re-encoding of the legacy per-rank builder output, and
//! parallel plan compilation must be representation-identical to the
//! serial pack for every worker count.
//!
//! Two oracles:
//!   * [`compile_rank_plans_serial`] — the pre-forge aggregate builders,
//!     kept verbatim as the reference emitter; and
//!   * `compile_plan_threads(.., 1)` — the serial incremental pack.
//!
//! Both property suites draw ≥100 random workloads across every
//! algorithm family × dense/sparse distributions × topology shapes via
//! the deterministic [`forall`] harness (failures print a replayable
//! case seed).

use tuna::algos::{
    compile_plan_threads, compile_rank_plans_serial, compile_segmented_plan, AlgoKind, GlobalAlgo,
    LocalAlgo, SegmentCompute,
};
use tuna::comm::{CommPlan, Engine, Topology};
use tuna::model::MachineProfile;
use tuna::util::prng::Pcg64;
use tuna::util::prop::forall;
use tuna::workload::{BlockSizes, Dist};

fn engine(p: usize, q: usize) -> Engine {
    Engine::new(MachineProfile::fugaku(), Topology::new(p, q))
}

/// Topology shapes with q | p and at least two ranks per node, so the
/// hierarchical compositions are always legal.
fn gen_shape(rng: &mut Pcg64) -> (usize, usize) {
    const SHAPES: [(usize, usize); 8] =
        [(8, 2), (8, 4), (9, 3), (12, 3), (12, 4), (16, 4), (16, 8), (24, 4)];
    SHAPES[rng.next_below(SHAPES.len() as u64) as usize]
}

fn gen_dist(rng: &mut Pcg64) -> Dist {
    let menu = [
        Dist::Uniform { max: 512 },
        Dist::normal_default(),
        Dist::powerlaw_default(),
        Dist::Const { size: 256 },
        Dist::FftN1,
        Dist::FftN2,
        Dist::Sparse { nnz: 4, max: 256 },
        Dist::Sparse { nnz: 2, max: 512 },
    ];
    menu[rng.next_below(menu.len() as u64) as usize]
}

/// Every one-shot compile family, including the paper's hierarchical
/// compositions (legal for all shapes [`gen_shape`] yields).
fn gen_kind(rng: &mut Pcg64) -> AlgoKind {
    let menu = [
        AlgoKind::SpreadOut,
        AlgoKind::OmpiLinear,
        AlgoKind::Pairwise,
        AlgoKind::Scattered { block_count: 3 },
        AlgoKind::Vendor,
        AlgoKind::Bruck2,
        AlgoKind::Tuna { radix: 2 },
        AlgoKind::Tuna { radix: 4 },
        AlgoKind::TunaAuto,
        AlgoKind::hier_coalesced(2, 2),
        AlgoKind::hier_staggered(2, 3),
        AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Linear },
        AlgoKind::Hier {
            local: LocalAlgo::Tuna { radix: 2 },
            global: GlobalAlgo::Bruck { radix: 2 },
        },
    ];
    menu[rng.next_below(menu.len() as u64) as usize]
}

struct Case {
    p: usize,
    q: usize,
    kind: AlgoKind,
    sizes: BlockSizes,
    label: String,
}

fn gen_case(rng: &mut Pcg64) -> Case {
    let (p, q) = gen_shape(rng);
    let dist = gen_dist(rng);
    let kind = gen_kind(rng);
    let seed = rng.next_below(1 << 20);
    let sizes = BlockSizes::generate(p, dist, seed);
    let label = format!("{} p={p} q={q} dist={} seed={seed}", kind.name(), dist.name());
    Case { p, q, kind, sizes, label }
}

/// Property: the interned arena decodes op-for-op to the legacy builder
/// output — per rank and in aggregate — and re-packing the builder
/// output reproduces the compiled plan bit-for-bit.
#[test]
fn interned_plan_decodes_op_for_op_to_the_legacy_builders() {
    forall("plan_ir_decode_equality", 120, |rng| {
        let c = gen_case(rng);
        let e = engine(c.p, c.q);
        let (ranks, t_peak, rounds) = compile_rank_plans_serial(&e, &c.kind, &c.sizes)
            .map_err(|err| format!("{}: reference compile failed: {err}", c.label))?;
        let plan = compile_plan_threads(&e, &c.kind, &c.sizes, 1)
            .map_err(|err| format!("{}: compile failed: {err}", c.label))?;
        if (plan.p, plan.q, plan.t_peak, plan.rounds) != (c.p, c.q, t_peak, rounds) {
            return Err(format!("{}: plan metadata diverged from reference", c.label));
        }
        let mut total = 0usize;
        let mut peak = 0usize;
        for (r, want) in ranks.iter().enumerate() {
            if plan.rank_len(r) != want.ops.len() {
                return Err(format!(
                    "{}: rank {r} op count {} != reference {}",
                    c.label,
                    plan.rank_len(r),
                    want.ops.len()
                ));
            }
            let got = plan.rank_plan(r);
            if got != *want {
                let pc = got
                    .ops
                    .iter()
                    .zip(&want.ops)
                    .position(|(a, b)| a != b)
                    .unwrap_or(got.ops.len());
                return Err(format!(
                    "{}: rank {r} decodes differently from op {pc}: {:?} vs {:?}",
                    c.label,
                    got.ops.get(pc),
                    want.ops.get(pc)
                ));
            }
            total += want.ops.len();
            peak = peak.max(want.ops.len());
        }
        if plan.total_ops() != total || plan.peak_rank_ops() != peak {
            return Err(format!(
                "{}: cached totals ({}, {}) != recomputed ({total}, {peak})",
                c.label,
                plan.total_ops(),
                plan.peak_rank_ops()
            ));
        }
        let repacked =
            CommPlan::from_rank_plans(c.p, c.q, c.kind.name(), ranks, t_peak, rounds);
        if repacked != plan {
            return Err(format!("{}: repack of reference output != compiled plan", c.label));
        }
        Ok(())
    });
}

/// Property: parallel compilation is representation-identical to the
/// serial pack for every worker count — same interning decisions, same
/// arena bytes, not merely the same decoded ops.
#[test]
fn parallel_compile_is_bit_identical_to_serial_for_every_thread_count() {
    forall("plan_ir_parallel_vs_serial", 100, |rng| {
        let c = gen_case(rng);
        let e = engine(c.p, c.q);
        let serial = compile_plan_threads(&e, &c.kind, &c.sizes, 1)
            .map_err(|err| format!("{}: serial compile failed: {err}", c.label))?;
        for threads in [2usize, 4, 8] {
            let par = compile_plan_threads(&e, &c.kind, &c.sizes, threads)
                .map_err(|err| format!("{}: {threads}-thread compile failed: {err}", c.label))?;
            if par != serial {
                return Err(format!("{}: {threads}-thread plan != serial plan", c.label));
            }
            if par.stats() != serial.stats() {
                return Err(format!("{}: {threads}-thread stats != serial stats", c.label));
            }
        }
        Ok(())
    });
}

/// Segmented plans stitch per-chunk compiles; the whole pipeline must
/// stay thread-count invariant end to end (engine knob, not explicit
/// thread argument — this is the path `mode=replay segments=K` takes).
#[test]
fn segmented_compile_is_thread_count_invariant() {
    let (p, q) = (16usize, 4usize);
    for dist in [Dist::Uniform { max: 512 }, Dist::Sparse { nnz: 4, max: 256 }] {
        let sizes = BlockSizes::generate(p, dist, 7);
        for kind in [AlgoKind::SpreadOut, AlgoKind::Tuna { radix: 4 }] {
            for segments in [2usize, 3] {
                for overlap in [false, true] {
                    for compute in [SegmentCompute::None, SegmentCompute::Uniform(2.0e-5)] {
                        let e1 = engine(p, q).with_compile_threads(Some(1));
                        let e4 = engine(p, q).with_compile_threads(Some(4));
                        let a = compile_segmented_plan(&e1, &kind, &sizes, segments, overlap, &compute)
                            .expect("serial segmented compile");
                        let b = compile_segmented_plan(&e4, &kind, &sizes, segments, overlap, &compute)
                            .expect("parallel segmented compile");
                        assert_eq!(
                            a,
                            b,
                            "{} dist={} segments={segments} overlap={overlap}: \
                             segmented plan depends on compile-threads",
                            kind.name(),
                            dist.name()
                        );
                    }
                }
            }
        }
    }
}

/// Interning effectiveness on the workload class it targets: a constant
/// (rotation-symmetric) dense workload under a linear family interns to
/// a single shared program, well under half the legacy footprint.
#[test]
fn const_dense_linear_interns_to_one_program() {
    let (p, q) = (256usize, 8usize);
    let e = engine(p, q);
    let sizes = BlockSizes::generate(p, Dist::Const { size: 512 }, 1);
    for kind in [AlgoKind::SpreadOut, AlgoKind::Pairwise] {
        let plan = compile_plan_threads(&e, &kind, &sizes, 4).expect("compile");
        let st = plan.stats();
        assert_eq!(
            st.distinct_programs,
            1,
            "{}: rotation-symmetric const workload should intern to one program",
            kind.name()
        );
        assert!(
            st.ratio() < 0.5,
            "{}: interned {} B vs legacy {} B (ratio {:.3})",
            kind.name(),
            st.plan_bytes,
            st.legacy_bytes,
            st.ratio()
        );
    }
    // Per-rank workloads (distinct rows) still round-trip, just without
    // sharing: every program stays addressable and decode stays lossless.
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 512 }, 1);
    let plan = compile_plan_threads(&e, &AlgoKind::SpreadOut, &sizes, 4).expect("compile");
    assert_eq!(plan.distinct_programs(), p);
}
