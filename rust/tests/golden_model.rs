//! Golden regression tests for the analytic model and the selector's
//! rankings. TSV snapshots live under `tests/golden/`; any drift in the
//! cost model or ranking logic fails here with a pointer to the
//! intentional-regeneration path.
//!
//! Bootstrap: on a fresh clone (no snapshot files) the current output is
//! written and the test passes with a notice; every later run compares.
//! Regenerate intentionally with `cargo run -- select --write-golden`
//! from `rust/` (or delete the files and re-run the tests).

use std::fs;
use std::path::PathBuf;

use tuna::algos::select;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Compare two snapshot TSVs: identical structure and non-numeric cells,
/// numeric cells equal within `rel` (absorbs libm differences between
/// hosts without letting real model changes through).
fn compare(golden: &str, current: &str, rel: f64) -> Result<(), String> {
    let g: Vec<&str> = golden.lines().collect();
    let c: Vec<&str> = current.lines().collect();
    if g.len() != c.len() {
        return Err(format!("line count changed: {} -> {}", g.len(), c.len()));
    }
    for (i, (gl, cl)) in g.iter().zip(&c).enumerate() {
        if gl == cl {
            continue;
        }
        let gcols: Vec<&str> = gl.split('\t').collect();
        let ccols: Vec<&str> = cl.split('\t').collect();
        if gcols.len() != ccols.len() {
            return Err(format!("line {}: column count changed", i + 1));
        }
        for (a, b) in gcols.iter().zip(&ccols) {
            if a == b {
                continue;
            }
            match (a.parse::<f64>(), b.parse::<f64>()) {
                (Ok(x), Ok(y)) => {
                    let tol = rel * x.abs().max(y.abs());
                    if (x - y).abs() > tol {
                        return Err(format!(
                            "line {}: {x} vs {y} differ beyond rel tol {rel}",
                            i + 1
                        ));
                    }
                }
                _ => return Err(format!("line {}: `{a}` vs `{b}`", i + 1)),
            }
        }
    }
    Ok(())
}

fn check_or_bootstrap(name: &str, current: &str) {
    let dir = golden_dir();
    let path = dir.join(name);
    if path.exists() {
        let golden = fs::read_to_string(&path).unwrap();
        if let Err(e) = compare(&golden, current, 1e-6) {
            panic!(
                "golden snapshot {name} drifted: {e}\n\
                 if the model change is intentional, regenerate with \
                 `cargo run -- select --write-golden` and commit the diff"
            );
        }
    } else {
        fs::create_dir_all(&dir).unwrap();
        fs::write(&path, current).unwrap();
        eprintln!("bootstrapped golden snapshot {name}; later runs compare against it");
    }
}

#[test]
fn estimator_snapshot_is_stable() {
    let current = select::golden_estimator_tsv();
    // Determinism within one process: two generations must be identical.
    assert_eq!(
        current,
        select::golden_estimator_tsv(),
        "estimator snapshot must be deterministic"
    );
    assert!(current.starts_with("# tuna-golden estimator v1"));
    check_or_bootstrap("estimator.tsv", &current);
}

#[test]
fn selector_ranking_snapshot_is_stable() {
    let current = select::golden_selector_tsv();
    assert_eq!(
        current,
        select::golden_selector_tsv(),
        "selector snapshot must be deterministic"
    );
    assert!(current.starts_with("# tuna-golden selector v1"));
    check_or_bootstrap("selector.tsv", &current);
}

#[test]
fn snapshot_comparer_catches_real_drift() {
    // The tolerance must absorb float noise but catch model changes.
    let base = "# h\na\t1.000000000000e-3\n";
    assert!(compare(base, "# h\na\t1.000000000001e-3\n", 1e-6).is_ok());
    assert!(compare(base, "# h\na\t1.100000000000e-3\n", 1e-6).is_err());
    assert!(compare(base, "# h\nb\t1.000000000000e-3\n", 1e-6).is_err());
    assert!(compare(base, "# h\n", 1e-6).is_err());
}
