//! Persistent-handle contract (PR 7): a [`PersistentColl`] must be a
//! pure amortization — every `start` call is **bit-identical** (makespan,
//! phase breakdown, counters, schedule stats) to the equivalent one-shot
//! `run_alltoallv` / `run_alltoallv_replay` invocation, across every
//! algorithm family, dense and sparse workloads, and both executors.
//! The only observable differences a handle is allowed are the ones it
//! exists for:
//!
//! * setup cost paid once at `init` instead of per call (plan
//!   compilation, transpose, fingerprints, payload arena);
//! * real-payload host copies amortized: one-shot runs copy
//!   2 x total_bytes (build + deliver), persistent starts copy
//!   total_bytes (the arena is built at init, deliveries still copy);
//! * access to the persistent-only `hier` local `balanced` schedule,
//!   which no one-shot entry point will run.
//!
//! Misuse (stale counts after the app regenerated its workload) must be
//! a typed [`TunaError`], never a panic.

use tuna::algos::{
    run_alltoallv, run_alltoallv_replay, AlgoKind, ExecMode, GlobalAlgo, LocalAlgo, RunReport,
};
use tuna::comm::{Engine, PersistentColl, Topology};
use tuna::model::MachineProfile;
use tuna::workload::{BlockSizes, Dist};
use tuna::TunaError;

fn engine(p: usize, q: usize) -> Engine {
    Engine::new(MachineProfile::fugaku(), Topology::new(p, q))
}

/// One representative per family, plus hier compositions covering every
/// global level (all legal at P = 12, Q = 4 → N = 3 nodes).
fn family_menu() -> Vec<AlgoKind> {
    vec![
        AlgoKind::SpreadOut,
        AlgoKind::OmpiLinear,
        AlgoKind::Pairwise,
        AlgoKind::Scattered { block_count: 2 },
        AlgoKind::Bruck2,
        AlgoKind::Tuna { radix: 2 },
        AlgoKind::TunaAuto,
        AlgoKind::hier_coalesced(2, 2),
        AlgoKind::hier_staggered(2, 1),
        AlgoKind::Hier {
            local: LocalAlgo::Linear,
            global: GlobalAlgo::Bruck { radix: 2 },
        },
        AlgoKind::Hier {
            local: LocalAlgo::Tuna { radix: 2 },
            global: GlobalAlgo::Linear,
        },
    ]
}

fn assert_reports_identical(kind: &AlgoKind, a: &RunReport, b: &RunReport) {
    let name = kind.name();
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{name}: makespan {} vs {}",
        a.makespan,
        b.makespan
    );
    assert_eq!(a.phases, b.phases, "{name}: phase breakdown");
    assert_eq!(a.counters, b.counters, "{name}: counters");
    assert_eq!(a.t_peak, b.t_peak, "{name}: t_peak");
    assert_eq!(a.rounds, b.rounds, "{name}: rounds");
}

#[test]
fn every_start_matches_the_one_shot_run_threaded() {
    let e = engine(12, 4);
    for dist in [
        Dist::Uniform { max: 512 },
        Dist::Sparse { nnz: 4, max: 512 },
    ] {
        let sizes = BlockSizes::generate(12, dist, 9);
        for kind in family_menu() {
            let oneshot = run_alltoallv(&e, &kind, &sizes, false).expect("one-shot threaded");
            let h = PersistentColl::init(&e, kind, &sizes, false, ExecMode::Threaded)
                .expect("persistent init");
            for _ in 0..3 {
                let rep = h.start(&sizes).expect("persistent start");
                assert_reports_identical(h.kind(), &oneshot, &rep);
                assert!(rep.validated);
            }
        }
    }
}

#[test]
fn every_start_matches_the_one_shot_run_replay() {
    let e = engine(12, 4);
    for dist in [
        Dist::Uniform { max: 512 },
        Dist::Sparse { nnz: 4, max: 512 },
    ] {
        let sizes = BlockSizes::generate(12, dist, 9);
        for kind in family_menu() {
            let oneshot = run_alltoallv_replay(&e, &kind, &sizes).expect("one-shot replay");
            let h = PersistentColl::init(&e, kind, &sizes, false, ExecMode::Replay)
                .expect("persistent init");
            assert!(h.plan().is_some());
            for _ in 0..3 {
                let rep = h.start(&sizes).expect("persistent start");
                assert_reports_identical(h.kind(), &oneshot, &rep);
            }
        }
    }
}

#[test]
fn threaded_and_replay_handles_agree() {
    let e = engine(12, 4);
    let sizes = BlockSizes::generate(12, Dist::Uniform { max: 256 }, 3);
    for kind in family_menu() {
        let t = PersistentColl::init(&e, kind, &sizes, false, ExecMode::Threaded)
            .unwrap()
            .start_frozen()
            .unwrap();
        let r = PersistentColl::init(&e, kind, &sizes, false, ExecMode::Replay)
            .unwrap()
            .start_frozen()
            .unwrap();
        assert_reports_identical(&kind, &t, &r);
    }
}

#[test]
fn stale_counts_are_a_typed_error_not_a_panic() {
    let e = engine(8, 2);
    let sizes = BlockSizes::generate(8, Dist::Uniform { max: 128 }, 1);
    let h = PersistentColl::init(&e, AlgoKind::Tuna { radix: 2 }, &sizes, false, ExecMode::Auto)
        .unwrap();

    // Same shape, regenerated counts: the classic stale-handle misuse.
    let drifted = BlockSizes::generate(8, Dist::Uniform { max: 128 }, 2);
    let err = h.start(&drifted).unwrap_err();
    assert!(matches!(err, TunaError::Config(_)), "{err}");
    assert!(err.to_string().contains("frozen at init"), "{err}");

    // Wrong P entirely.
    let wrong_p = BlockSizes::generate(4, Dist::Uniform { max: 128 }, 1);
    assert!(matches!(h.start(&wrong_p).unwrap_err(), TunaError::Config(_)));

    // The handle is not poisoned by rejected starts.
    let good = h.start(&sizes).unwrap();
    assert!(good.validated);
}

#[test]
fn balanced_local_schedule_is_persistent_only() {
    // The spec never parses: tuning tables and golden grids cannot
    // carry the kind, so it can only enter through a handle.
    assert!(LocalAlgo::parse("balanced").is_err());
    let parse_err = AlgoKind::parse("hier:l=balanced,g=linear").unwrap_err().to_string();
    assert!(parse_err.contains("persistent-only"), "{parse_err}");

    let balanced = AlgoKind::Hier {
        local: LocalAlgo::Balanced,
        global: GlobalAlgo::Linear,
    };
    let e = engine(12, 4);
    // Skewed blocks so the heavy-first drain order is not the identity.
    let sizes = BlockSizes::generate(12, Dist::Sparse { nnz: 6, max: 1024 }, 11);

    // Both one-shot entry points refuse the kind.
    let err = run_alltoallv(&e, &balanced, &sizes, false).unwrap_err().to_string();
    assert!(err.contains("persistent-only"), "{err}");
    let err = run_alltoallv_replay(&e, &balanced, &sizes).unwrap_err().to_string();
    assert!(err.contains("persistent-only"), "{err}");

    // A handle is the authorization: both executors run it, repeated
    // starts are stable, and threaded and replay agree bit for bit.
    let t = PersistentColl::init(&e, balanced, &sizes, false, ExecMode::Threaded).unwrap();
    let r = PersistentColl::init(&e, balanced, &sizes, false, ExecMode::Replay).unwrap();
    let t1 = t.start_frozen().unwrap();
    let t2 = t.start_frozen().unwrap();
    let r1 = r.start_frozen().unwrap();
    assert_reports_identical(&balanced, &t1, &t2);
    assert_reports_identical(&balanced, &t1, &r1);
    assert!(t1.validated);
}

#[test]
fn real_mode_persistent_amortizes_host_copies() {
    let e = engine(8, 2);
    let sizes = BlockSizes::generate(8, Dist::Uniform { max: 256 }, 5);
    let total = sizes.total_bytes();
    for kind in [AlgoKind::Tuna { radix: 2 }, AlgoKind::SpreadOut] {
        // One-shot real mode builds the payloads (total_bytes) and
        // delivers them (total_bytes again): the 2x zero-copy invariant.
        let oneshot = run_alltoallv(&e, &kind, &sizes, true).unwrap();
        assert_eq!(oneshot.counters.copied_bytes, 2 * total, "{}", kind.name());

        // Persistent real mode builds the arena once at init; every
        // start only pays the delivery copies. Timing is unchanged.
        let h = PersistentColl::init(&e, kind, &sizes, true, ExecMode::Auto).unwrap();
        assert_eq!(h.mode(), ExecMode::Threaded);
        for _ in 0..2 {
            let rep = h.start_frozen().unwrap();
            assert_eq!(rep.counters.copied_bytes, total, "{}", kind.name());
            assert_eq!(
                rep.makespan.to_bits(),
                oneshot.makespan.to_bits(),
                "{}: real-mode persistent makespan drifted",
                kind.name()
            );
            assert!(rep.validated);
        }
    }
}

#[test]
fn replay_handles_are_phantom_only_and_auto_resolves() {
    let e = engine(8, 2);
    let sizes = BlockSizes::generate(8, Dist::Uniform { max: 64 }, 2);
    let err = PersistentColl::init(&e, AlgoKind::Bruck2, &sizes, true, ExecMode::Replay)
        .unwrap_err()
        .to_string();
    assert!(err.contains("phantom-only"), "{err}");
    // Phantom + Auto resolves to replay and shares the engine plan cache.
    let h = PersistentColl::init(&e, AlgoKind::Bruck2, &sizes, false, ExecMode::Auto).unwrap();
    assert_eq!(h.mode(), ExecMode::Replay);
    assert!(h.shards() >= 1);
    assert!(h.start(&sizes).is_ok());
}
