//! Property-based correctness suite: randomized (P, Q, dist, AlgoKind)
//! cases with real byte-pattern payloads, plus the selector/heuristic
//! contract that no emitted configuration is ever rejected by
//! [`AlgoKind::check`]. Failures report the case index and seed so they
//! reproduce exactly (`util::prop::forall`).

use tuna::algos::{hier, run_alltoallv, select, tuning, AlgoKind, GlobalAlgo, LocalAlgo};
use tuna::comm::{Engine, Topology};
use tuna::model::MachineProfile;
use tuna::util::prng::Pcg64;
use tuna::util::prop::forall;
use tuna::workload::{BlockSizes, Dist};

/// Random topology: Q in {1, 2, 3, 4}, 1..=5 nodes, P = Q·N >= 2.
fn gen_topology(rng: &mut Pcg64) -> (usize, usize) {
    let q = [1usize, 2, 3, 4][rng.next_below(4) as usize];
    let nodes = 1 + rng.next_below(5) as usize;
    let p = (q * nodes).max(2);
    let q = if p % q == 0 { q } else { 1 };
    (p, q)
}

fn gen_dist(rng: &mut Pcg64) -> Dist {
    match rng.next_below(6) {
        0 => Dist::Uniform {
            max: 8 * (1 + rng.next_below(128)),
        },
        1 => Dist::normal_default(),
        2 => Dist::powerlaw_default(),
        3 => Dist::Const {
            size: 1 + rng.next_below(512),
        },
        4 => Dist::FftN1,
        _ => Dist::FftN2,
    }
}

/// Random algorithm over every family, parameters drawn inside the
/// ranges `AlgoKind::check` admits for (p, q).
fn gen_kind(rng: &mut Pcg64, p: usize, q: usize) -> AlgoKind {
    loop {
        match rng.next_below(10) {
            0 => return AlgoKind::SpreadOut,
            1 => return AlgoKind::OmpiLinear,
            2 => return AlgoKind::Pairwise,
            3 => {
                return AlgoKind::Scattered {
                    block_count: 1 + rng.next_below(p as u64) as usize,
                }
            }
            4 => return AlgoKind::Vendor,
            5 => return AlgoKind::Bruck2,
            6 => {
                return AlgoKind::Tuna {
                    radix: (2 + rng.next_below(p as u64) as usize).min(p.max(2)),
                }
            }
            7 => return AlgoKind::TunaAuto,
            8 | 9 if q >= 2 && p / q >= 2 => {
                return hier::random_composition(rng, q, p / q)
            }
            _ => continue,
        }
    }
}

/// Random [`AlgoKind`] over *every* variant with arbitrary (not
/// necessarily runnable) parameters — parse/spec round-tripping must not
/// depend on topology validity.
fn gen_any_kind(rng: &mut Pcg64) -> AlgoKind {
    let num = |rng: &mut Pcg64| 1 + rng.next_below(9999) as usize;
    match rng.next_below(9) {
        0 => AlgoKind::SpreadOut,
        1 => AlgoKind::OmpiLinear,
        2 => AlgoKind::Pairwise,
        3 => AlgoKind::Scattered { block_count: num(rng) },
        4 => AlgoKind::Vendor,
        5 => AlgoKind::Bruck2,
        6 => AlgoKind::Tuna { radix: num(rng) },
        7 => AlgoKind::TunaAuto,
        _ => {
            let local = match rng.next_below(2) {
                0 => LocalAlgo::Tuna { radix: num(rng) },
                _ => LocalAlgo::Linear,
            };
            let global = match rng.next_below(4) {
                0 => GlobalAlgo::Coalesced { block_count: num(rng) },
                1 => GlobalAlgo::Staggered { block_count: num(rng) },
                2 => GlobalAlgo::Linear,
                _ => GlobalAlgo::Bruck { radix: num(rng) },
            };
            AlgoKind::Hier { local, global }
        }
    }
}

#[test]
fn spec_round_trip_is_exhaustive_over_variants() {
    // parse(spec(k)) == k for every variant — including every
    // local×global composition — with randomized parameters, and the
    // legacy `tuna-hier-*` aliases keep resolving to the equivalent
    // composition.
    forall("AlgoKind spec round-trip", 300, |rng| {
        let kind = gen_any_kind(rng);
        let spec = kind.spec();
        match AlgoKind::parse(&spec) {
            Ok(back) if back == kind => {}
            Ok(back) => return Err(format!("{spec}: parsed back as {}", back.spec())),
            Err(e) => return Err(format!("{spec}: failed to re-parse: {e}")),
        }
        // The human-readable name stays distinct per parameterization
        // (spot check: it embeds the same spec'd parameters).
        if kind.name().is_empty() {
            return Err(format!("{spec}: empty name"));
        }
        // Legacy aliases, driven by the same random parameters.
        let (r, b) = (1 + rng.next_below(999) as usize, 1 + rng.next_below(999) as usize);
        let co = AlgoKind::parse(&format!("tuna-hier-coalesced:r={r},b={b}"))
            .map_err(|e| e.to_string())?;
        if co != AlgoKind::hier_coalesced(r, b) {
            return Err(format!("coalesced alias r={r} b={b} parsed as {}", co.spec()));
        }
        if AlgoKind::parse(&co.spec()).map_err(|e| e.to_string())? != co {
            return Err(format!("coalesced alias does not round-trip: {}", co.spec()));
        }
        let st = AlgoKind::parse(&format!("tuna-hier-staggered:r={r},b={b}"))
            .map_err(|e| e.to_string())?;
        if st != AlgoKind::hier_staggered(r, b) {
            return Err(format!("staggered alias r={r} b={b} parsed as {}", st.spec()));
        }
        Ok(())
    });
}

#[test]
fn alltoallv_randomized_real_payloads() {
    forall("alltoallv randomized (P, Q, dist, kind)", 220, |rng| {
        let (p, q) = gen_topology(rng);
        let dist = gen_dist(rng);
        let kind = gen_kind(rng, p, q);
        let seed = rng.next_u64();
        let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, dist, seed);
        match run_alltoallv(&engine, &kind, &sizes, true) {
            Ok(rep) if rep.validated && rep.makespan > 0.0 => Ok(()),
            Ok(rep) => Err(format!(
                "{} P={p} Q={q} {dist:?}: invalid result (makespan {})",
                kind.name(),
                rep.makespan
            )),
            Err(e) => Err(format!("{} P={p} Q={q} {dist:?}: {e}", kind.name())),
        }
    });
}

#[test]
fn sparse_workloads_round_trip_every_family_real_payloads() {
    // Structural sparsity: zero-size entries are *absent* — no block, no
    // message, no rope segment. Every family must deliver exactly the
    // structural block set (the validator counts blocks per rank, so a
    // phantom send for an absent pair fails loudly), with real payload
    // bytes intact, across empty rows, self-only rows and nnz = 0.
    forall("sparse alltoallv randomized (P, Q, nnz, kind)", 120, |rng| {
        let (p, q) = gen_topology(rng);
        let nnz = rng.next_below(p as u64 + 1) as usize;
        let dist = Dist::Sparse {
            nnz,
            max: 8 * (1 + rng.next_below(64)),
        };
        let kind = gen_kind(rng, p, q);
        let seed = rng.next_u64();
        let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, dist, seed);
        match run_alltoallv(&engine, &kind, &sizes, true) {
            Ok(rep) if rep.validated => Ok(()),
            Ok(_) => Err(format!("{} P={p} Q={q} nnz={nnz}: invalid result", kind.name())),
            Err(e) => Err(format!("{} P={p} Q={q} nnz={nnz}: {e}", kind.name())),
        }
    });
}

#[test]
fn sparse_linear_families_send_no_phantom_messages() {
    // For the direct-shipping families the data message count is exactly
    // the off-diagonal structural entry count — absent pairs produce no
    // traffic at all (and an empty matrix produces zero messages).
    let p = 24;
    let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, 4));
    let sizes = BlockSizes::generate(p, Dist::Sparse { nnz: 5, max: 256 }, 3);
    let offdiag: u64 = (0..p)
        .map(|s| {
            sizes
                .row_view(s)
                .entries()
                .filter(|&(d, _)| d != s)
                .count() as u64
        })
        .sum();
    for kind in [
        AlgoKind::SpreadOut,
        AlgoKind::OmpiLinear,
        AlgoKind::Pairwise,
        AlgoKind::Scattered { block_count: 3 },
    ] {
        let rep = run_alltoallv(&engine, &kind, &sizes, true).unwrap();
        assert_eq!(
            rep.counters.total_msgs(),
            offdiag,
            "{}: phantom sends on a sparse workload",
            kind.name()
        );
    }
    // Fully empty matrix: zero messages, still valid.
    let empty = BlockSizes::generate(p, Dist::Sparse { nnz: 0, max: 256 }, 3);
    let rep = run_alltoallv(&engine, &AlgoKind::SpreadOut, &empty, true).unwrap();
    assert_eq!(rep.counters.total_msgs(), 0);
    assert!(rep.validated);
}

#[test]
fn csr_zero_entries_and_empty_rows_round_trip() {
    // Hand-built CSR rows: explicit zeros are dropped at construction
    // (structurally absent), empty send rows coexist with full ones, and
    // every family delivers the exact structural set in real mode.
    let p = 12;
    let q = 4;
    let mut rows: Vec<Vec<(usize, u64)>> = vec![Vec::new(); p];
    rows[0] = vec![(1, 8), (4, 0), (9, 32)]; // zero entry dropped
    rows[3] = vec![(3, 16)]; // self only
    rows[5] = (0..p).map(|d| (d, 24)).collect(); // full row
    rows[11] = vec![(0, 8)];
    let sizes = BlockSizes::from_sparse_rows(p, rows);
    assert_eq!(sizes.nnz_row(0), 2, "zero entry must be structurally absent");
    let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
    for kind in [
        AlgoKind::SpreadOut,
        AlgoKind::Pairwise,
        AlgoKind::Tuna { radix: 2 },
        AlgoKind::TunaAuto,
        AlgoKind::hier_coalesced(2, 1),
        AlgoKind::hier_staggered(2, 4),
        AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 2 } },
        AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Linear },
    ] {
        let rep = run_alltoallv(&engine, &kind, &sizes, true)
            .unwrap_or_else(|e| panic!("{}: {e}", kind.name()));
        assert!(rep.validated, "{}", kind.name());
    }
}

#[test]
fn selector_and_heuristic_never_emit_invalid_params() {
    forall("selector/heuristic params pass AlgoKind::check", 220, |rng| {
        // Paper-scale topologies too: validity must not depend on the
        // engine's comfort zone.
        let q = [1usize, 2, 4, 8, 16, 32][rng.next_below(6) as usize];
        let nodes = 1 + rng.next_below(64) as usize;
        let p = (q * nodes).max(2);
        let q = if p % q == 0 { q } else { 1 };
        // Log-uniform mean block size in [1 B, 1 MiB].
        let mean = (2f64).powf(rng.next_f64() * 20.0);

        let heur = AlgoKind::Tuna {
            radix: tuning::heuristic_radix(p, mean),
        };
        heur.check(p, q)
            .map_err(|e| format!("heuristic P={p} Q={q} mean={mean:.1}: {e}"))?;

        let pool = select::candidate_pool(p, q);
        if pool.is_empty() {
            return Err(format!("empty candidate pool for P={p} Q={q}"));
        }
        for kind in &pool {
            kind.check(p, q)
                .map_err(|e| format!("pool P={p} Q={q} {}: {e}", kind.name()))?;
        }

        // The ranking preserves the pool, so its top pick is valid too
        // (bounded to modest P to keep the estimator loop cheap here).
        if p <= 256 {
            let ranked = select::model_rank(
                &MachineProfile::fugaku(),
                Topology::new(p, q),
                mean,
                &pool,
            );
            ranked[0]
                .kind
                .check(p, q)
                .map_err(|e| format!("top-1 P={p} Q={q}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn tuna_auto_matches_explicit_heuristic_radix() {
    // `tuna:auto` must execute exactly TuNA at the heuristic radix for
    // the global mean block size: same round count, and identical
    // traffic plus the one mean-agreement allreduce.
    let (p, q) = (16usize, 4usize);
    let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
    for (dist, seed) in [
        (Dist::Uniform { max: 64 }, 7u64),
        (Dist::Uniform { max: 4096 }, 8),
        (Dist::powerlaw_default(), 9),
    ] {
        let sizes = BlockSizes::generate(p, dist, seed);
        let total: u64 = (0..p).map(|s| sizes.row(s).iter().sum::<u64>()).sum();
        let mean = total as f64 / (p * p) as f64;
        let radix = tuning::heuristic_radix(p, mean);

        let auto = run_alltoallv(&engine, &AlgoKind::TunaAuto, &sizes, true).unwrap();
        let fixed = run_alltoallv(&engine, &AlgoKind::Tuna { radix }, &sizes, true).unwrap();
        assert_eq!(auto.rounds, fixed.rounds, "dist {dist:?}");
        assert!(
            auto.counters.total_msgs() >= fixed.counters.total_msgs(),
            "auto must pay for its agreement allreduce ({} < {})",
            auto.counters.total_msgs(),
            fixed.counters.total_msgs()
        );
        assert_eq!(
            auto.counters.total_bytes() - fixed.counters.total_bytes(),
            8 * (auto.counters.total_msgs() - fixed.counters.total_msgs()),
            "extra traffic must be exactly the 8 B/msg allreduce scalars"
        );
    }
}
