//! Shape-level reproduction checks: the qualitative findings of the
//! paper's evaluation must hold on the simulated machines (who wins, in
//! which regime, and what the tunables do) — DESIGN.md §5's "headline
//! claims to reproduce in shape".

use tuna::algos::{run_alltoallv, tuning, AlgoKind};
use tuna::comm::{Engine, Topology};
use tuna::model::MachineProfile;
use tuna::workload::{BlockSizes, Dist};

fn median_time(engine: &Engine, kind: &AlgoKind, dist: Dist, iters: usize) -> f64 {
    let mut times: Vec<f64> = (0..iters)
        .map(|i| {
            let sizes = BlockSizes::generate(engine.topo.p(), dist, 1000 + i as u64);
            run_alltoallv(engine, kind, &sizes, false).unwrap().makespan
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// §V-A / Fig. 8: TuNA with a good radix decisively beats the vendor
/// linear implementation for small messages at scale.
#[test]
fn tuna_beats_vendor_small_messages() {
    for profile in [MachineProfile::polaris(), MachineProfile::fugaku()] {
        let engine = Engine::new(profile.clone(), Topology::new(256, 8));
        let dist = Dist::Uniform { max: 16 };
        let tuna = median_time(&engine, &AlgoKind::Tuna { radix: 2 }, dist, 3);
        let vendor = median_time(&engine, &AlgoKind::Vendor, dist, 3);
        assert!(
            vendor / tuna > 3.0,
            "{}: expected >3x at S=16, got {:.2}x",
            profile.name,
            vendor / tuna
        );
    }
}

/// §V-A: at large S the advantage shrinks or inverts (bandwidth regime)
/// — the vendor/scattered linear path moves each byte once while radix-2
/// TuNA forwards bytes log P times.
#[test]
fn tuna_radix2_loses_large_messages() {
    let engine = Engine::new(MachineProfile::polaris(), Topology::new(128, 8));
    let dist = Dist::Uniform { max: 64 * 1024 };
    let tuna2 = median_time(&engine, &AlgoKind::Tuna { radix: 2 }, dist, 3);
    let vendor = median_time(&engine, &AlgoKind::Vendor, dist, 3);
    assert!(
        tuna2 > vendor,
        "radix-2 TuNA ({tuna2}) should lose to vendor ({vendor}) at 64 KiB"
    );
}

/// Fig. 7: the ideal radix is non-decreasing in S (latency regime ->
/// balanced -> bandwidth regime).
#[test]
fn ideal_radix_grows_with_message_size() {
    let p = 256;
    let engine = Engine::new(MachineProfile::polaris(), Topology::new(p, 8));
    let mut last_best = 0usize;
    for s in [16u64, 1024, 65536] {
        let dist = Dist::Uniform { max: s };
        let best = tuning::radix_candidates(p)
            .into_iter()
            .min_by(|&a, &b| {
                let ta = median_time(&engine, &AlgoKind::Tuna { radix: a }, dist, 1);
                let tb = median_time(&engine, &AlgoKind::Tuna { radix: b }, dist, 1);
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap();
        assert!(
            best >= last_best,
            "ideal radix must not shrink as S grows (S={s}: {best} < {last_best})"
        );
        last_best = best;
    }
    assert!(last_best >= 16, "large S should favor a large radix");
}

/// §V-B / Fig. 10: coalesced TuNA_l^g beats staggered at small S (fewer
/// inter-node messages), and the gap closes at large S.
#[test]
fn coalesced_beats_staggered_small_s() {
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(128, 8));
    let small = Dist::Uniform { max: 16 };
    let co = median_time(
        &engine,
        &AlgoKind::hier_coalesced(2, 4),
        small,
        3,
    );
    let st = median_time(
        &engine,
        &AlgoKind::hier_staggered(2, 32),
        small,
        3,
    );
    assert!(
        st / co > 2.0,
        "coalesced should win clearly at S=16: staggered {st} vs coalesced {co}"
    );

    let large = Dist::Uniform { max: 16 * 1024 };
    let co_l = median_time(
        &engine,
        &AlgoKind::hier_coalesced(2, 4),
        large,
        3,
    );
    let st_l = median_time(
        &engine,
        &AlgoKind::hier_staggered(2, 32),
        large,
        3,
    );
    assert!(
        st_l / co_l < st / co,
        "the staggered/coalesced gap must shrink at large S ({:.2} vs {:.2})",
        st_l / co_l,
        st / co
    );
}

/// Fig. 13 shape: the hierarchical coalesced variant is the overall
/// winner at small S, beating flat TuNA too.
#[test]
fn coalesced_hier_is_overall_winner_small_s() {
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(256, 32));
    let dist = Dist::Uniform { max: 64 };
    let sizes = BlockSizes::generate(256, dist, 3);
    let tuna = tuning::autotune_tuna(&engine, &sizes).unwrap().best_time;
    let coal = tuning::autotune_hier(&engine, &sizes, true).unwrap().best_time;
    let vendor = run_alltoallv(&engine, &AlgoKind::Vendor, &sizes, false)
        .unwrap()
        .makespan;
    assert!(coal < tuna, "coalesced ({coal}) should beat flat tuna ({tuna})");
    assert!(
        vendor / coal > 5.0,
        "coalesced should be >5x over vendor at small S ({:.1}x)",
        vendor / coal
    );
}

/// Fig. 12: OpenMPI's ascending linear is the worst baseline at scale.
#[test]
fn ompi_linear_is_worst_baseline() {
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(256, 8));
    let dist = Dist::Uniform { max: 2048 };
    let ompi = median_time(&engine, &AlgoKind::OmpiLinear, dist, 3);
    for other in [AlgoKind::SpreadOut, AlgoKind::Pairwise, AlgoKind::Vendor] {
        let t = median_time(&engine, &other, dist, 3);
        assert!(
            ompi >= t * 0.98,
            "{} ({t}) should not be slower than ompi-linear ({ompi})",
            other.name()
        );
    }
}

/// §V-B: the ideal block_count for the inter-node phase decreases as S
/// grows (congestion outweighs latency hiding for big messages).
#[test]
fn ideal_block_count_shrinks_with_s() {
    let p = 256;
    let q = 8;
    let n = p / q;
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let best_bc = |s: u64| -> usize {
        tuning::block_count_candidates((n - 1) * q)
            .into_iter()
            .min_by(|&a, &b| {
                let ka = AlgoKind::hier_staggered(2, a);
                let kb = AlgoKind::hier_staggered(2, b);
                let ta = median_time(&engine, &ka, Dist::Uniform { max: s }, 1);
                let tb = median_time(&engine, &kb, Dist::Uniform { max: s }, 1);
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap()
    };
    let bc_small = best_bc(16);
    let bc_large = best_bc(32 * 1024);
    assert!(
        bc_large <= bc_small,
        "ideal block_count must not grow with S: S=16 -> {bc_small}, S=32K -> {bc_large}"
    );
}
