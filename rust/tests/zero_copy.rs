//! The zero-copy payload invariant (PR 2): real-mode payloads are rope
//! views over Arc-shared storage, so across any store-and-forward
//! schedule the host moves each payload byte exactly twice — written once
//! at its source (the per-rank pattern arena) and read once at its sink
//! (pattern verification). `Counters::copied_bytes` tracks those host
//! moves; any intermediate hop that copied payload bytes would amplify it
//! beyond `2 * total_payload_bytes` and fail these properties.
//!
//! This is the host-side complement of `Counters::bytes_copied`, the
//! *modeled* pack/unpack charge on the virtual clock, which is intact and
//! unchanged by the rope representation.

use tuna::algos::{hier, run_alltoallv, AlgoKind, GlobalAlgo, LocalAlgo};
use tuna::comm::{Engine, Topology};
use tuna::model::MachineProfile;
use tuna::util::prng::Pcg64;
use tuna::util::prop::forall;
use tuna::workload::{BlockSizes, Dist};

/// Random topology: Q in {1, 2, 3, 4}, 1..=5 nodes, P = Q·N >= 2.
fn gen_topology(rng: &mut Pcg64) -> (usize, usize) {
    let q = [1usize, 2, 3, 4][rng.next_below(4) as usize];
    let nodes = 1 + rng.next_below(5) as usize;
    let p = (q * nodes).max(2);
    let q = if p % q == 0 { q } else { 1 };
    (p, q)
}

fn gen_dist(rng: &mut Pcg64) -> Dist {
    match rng.next_below(5) {
        0 => Dist::Uniform {
            max: 8 * (1 + rng.next_below(128)),
        },
        1 => Dist::normal_default(),
        2 => Dist::powerlaw_default(),
        3 => Dist::Const {
            size: 1 + rng.next_below(512),
        },
        _ => Dist::FftN1,
    }
}

/// Store-and-forward kinds — the ones whose hops could plausibly copy.
fn gen_forwarding_kind(rng: &mut Pcg64, p: usize, q: usize) -> AlgoKind {
    loop {
        match rng.next_below(4) {
            0 => return AlgoKind::Bruck2,
            1 => {
                return AlgoKind::Tuna {
                    radix: (2 + rng.next_below(p as u64) as usize).min(p.max(2)),
                }
            }
            2 => return AlgoKind::TunaAuto,
            3 if q >= 2 && p / q >= 2 => {
                return hier::random_composition(rng, q, p / q)
            }
            _ => continue,
        }
    }
}

#[test]
fn tuna_and_hier_hops_copy_zero_payload_bytes() {
    forall("zero-copy invariant (store-and-forward)", 60, |rng| {
        let (p, q) = gen_topology(rng);
        let dist = gen_dist(rng);
        let kind = gen_forwarding_kind(rng, p, q);
        let seed = rng.next_u64();
        let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, dist, seed);
        let rep = run_alltoallv(&engine, &kind, &sizes, true)
            .map_err(|e| format!("{} P={p} Q={q} {dist:?}: {e}", kind.name()))?;
        let expect = 2 * sizes.total_bytes();
        if rep.counters.copied_bytes == expect {
            Ok(())
        } else {
            Err(format!(
                "{} P={p} Q={q} {dist:?}: copied {} B != write-once+read-once {} B \
                 ({} rounds amplified intermediate copies?)",
                kind.name(),
                rep.counters.copied_bytes,
                expect,
                rep.rounds
            ))
        }
    });
}

#[test]
fn linear_families_satisfy_the_same_bound() {
    // Direct-shipping algorithms trivially must not copy either; pin it.
    let p = 12;
    let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, 4));
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 777 }, 5);
    for kind in [
        AlgoKind::SpreadOut,
        AlgoKind::OmpiLinear,
        AlgoKind::Pairwise,
        AlgoKind::Scattered { block_count: 3 },
        AlgoKind::Vendor,
    ] {
        let rep = run_alltoallv(&engine, &kind, &sizes, true).unwrap();
        assert_eq!(
            rep.counters.copied_bytes,
            2 * sizes.total_bytes(),
            "{}",
            kind.name()
        );
    }
}

#[test]
fn composition_grid_satisfies_the_write_once_read_once_bound() {
    // The satellite grid: at least four distinct local×global
    // compositions (including both legacy pairings), each moving every
    // payload byte exactly twice on the host.
    let (p, q) = (12usize, 4usize);
    let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 640 }, 17);
    let grid = [
        AlgoKind::hier_coalesced(2, 2), // legacy Alg. 3 pairing
        AlgoKind::hier_staggered(3, 4), // legacy Alg. 2 pairing
        AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Linear },
        AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 3 } },
        AlgoKind::Hier {
            local: LocalAlgo::Tuna { radix: 4 },
            global: GlobalAlgo::Bruck { radix: 2 },
        },
        AlgoKind::Hier { local: LocalAlgo::Tuna { radix: 2 }, global: GlobalAlgo::Linear },
    ];
    assert!(grid.len() >= 4);
    for kind in grid {
        let rep = run_alltoallv(&engine, &kind, &sizes, true).unwrap();
        assert_eq!(
            rep.counters.copied_bytes,
            2 * sizes.total_bytes(),
            "{}",
            kind.name()
        );
    }
}

#[test]
fn sparse_workloads_satisfy_write_once_read_once() {
    // Sparse real-mode runs write only the structural bytes into the
    // per-rank arenas and read each delivered block once: the invariant
    // is still exactly 2 x total (structural) bytes — absent pairs
    // contribute no arena bytes, no messages and no rope segments.
    forall("zero-copy invariant (sparse)", 30, |rng| {
        let (p, q) = gen_topology(rng);
        let nnz = rng.next_below(p as u64 + 1) as usize;
        let kind = gen_forwarding_kind(rng, p, q);
        let sizes = BlockSizes::generate(
            p,
            Dist::Sparse { nnz, max: 8 * (1 + rng.next_below(64)) },
            rng.next_u64(),
        );
        let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let rep = run_alltoallv(&engine, &kind, &sizes, true)
            .map_err(|e| format!("{} P={p} Q={q} nnz={nnz}: {e}", kind.name()))?;
        let expect = 2 * sizes.total_bytes();
        if rep.counters.copied_bytes == expect {
            Ok(())
        } else {
            Err(format!(
                "{} P={p} Q={q} nnz={nnz}: copied {} B != {} B",
                kind.name(),
                rep.counters.copied_bytes,
                expect
            ))
        }
    });
    // The sparse linear families hold the same bound.
    let p = 16;
    let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, 4));
    let sizes = BlockSizes::generate(p, Dist::Sparse { nnz: 4, max: 512 }, 7);
    for kind in [
        AlgoKind::SpreadOut,
        AlgoKind::Pairwise,
        AlgoKind::Scattered { block_count: 2 },
    ] {
        let rep = run_alltoallv(&engine, &kind, &sizes, true).unwrap();
        assert_eq!(rep.counters.copied_bytes, 2 * sizes.total_bytes(), "{}", kind.name());
    }
}

#[test]
fn zero_size_blocks_carry_no_rope_segments() {
    // Dense rows may sample genuine zero-size blocks; their buffers must
    // be empty ropes (no segments), and a dense run whose matrix
    // contains zeros still satisfies the write-once/read-once bound.
    use tuna::comm::DataBuf;
    let row = DataBuf::pattern_row(1, &[16, 0, 8, 0]);
    assert_eq!(row[1].rope().segment_count(), 0);
    assert_eq!(row[3].rope().segment_count(), 0);
    assert_eq!(row[0].rope().segment_count(), 1);
    // PowerLaw with heavy skew samples plenty of zeros.
    let p = 12;
    let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, 4));
    let sizes = BlockSizes::generate(p, Dist::PowerLaw { max: 64, skew: 6.0 }, 5);
    for kind in [AlgoKind::SpreadOut, AlgoKind::Tuna { radix: 2 }, AlgoKind::hier_coalesced(2, 2)]
    {
        let rep = run_alltoallv(&engine, &kind, &sizes, true).unwrap();
        assert_eq!(rep.counters.copied_bytes, 2 * sizes.total_bytes(), "{}", kind.name());
    }
}

#[test]
fn phantom_mode_moves_no_host_bytes() {
    let p = 16;
    let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, 4));
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 4096 }, 9);
    for kind in [
        AlgoKind::Tuna { radix: 2 },
        AlgoKind::hier_staggered(2, 3),
        AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 2 } },
        AlgoKind::SpreadOut,
    ] {
        let rep = run_alltoallv(&engine, &kind, &sizes, false).unwrap();
        assert_eq!(rep.counters.copied_bytes, 0, "{}", kind.name());
        // The modeled pack/unpack charge is mode-independent and intact.
        assert!(rep.counters.bytes_copied > 0, "{}", kind.name());
    }
}
