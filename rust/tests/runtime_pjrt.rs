//! PJRT runtime integration: execute the AOT-lowered Pallas/JAX
//! artifacts from Rust and validate numerics against the naive oracle.
//! Skips (with a notice) when `make artifacts` has not been run — CI
//! without jax can still run the rest of the suite. The whole file is
//! gated on the `pjrt` feature because the default offline build has no
//! `xla` crate to execute artifacts with.
#![cfg(feature = "pjrt")]

use tuna::apps::fft::{dft_matrix, twiddles, CMat};
use tuna::runtime::PjrtRuntime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT test: run `make artifacts` first");
        None
    }
}

fn cmatmul_ref(a: &CMat, b: &CMat) -> CMat {
    let mut out = CMat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            for j in 0..b.cols {
                let (ar, ai) = (a.re[i * a.cols + k], a.im[i * a.cols + k]);
                let (br, bi) = (b.re[k * b.cols + j], b.im[k * b.cols + j]);
                out.re[i * out.cols + j] += ar * br - ai * bi;
                out.im[i * out.cols + j] += ar * bi + ai * br;
            }
        }
    }
    out
}

fn randomish(rows: usize, cols: usize, seed: u64) -> CMat {
    let mut rng = tuna::util::prng::Pcg64::new(seed, 0);
    let mut m = CMat::zeros(rows, cols);
    for i in 0..rows * cols {
        m.re[i] = (rng.next_f64() * 2.0 - 1.0) as f32;
        m.im[i] = (rng.next_f64() * 2.0 - 1.0) as f32;
    }
    m
}

#[test]
fn stage2_artifact_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::open(&dir).unwrap();
    assert!(rt.has("fft_stage2_16x4"), "manifest should list fft_stage2_16x4");

    let f = dft_matrix(16);
    let a = randomish(16, 4, 42);
    let dims_f = [16i64, 16];
    let dims_a = [16i64, 4];
    let out = rt
        .execute_f32(
            "fft_stage2_16x4",
            &[(&f.re, &dims_f), (&f.im, &dims_f), (&a.re, &dims_a), (&a.im, &dims_a)],
        )
        .unwrap();
    let want = cmatmul_ref(&f, &a);
    assert_eq!(out[0].len(), 64);
    for i in 0..64 {
        assert!((out[0][i] - want.re[i]).abs() < 1e-3, "re[{i}]");
        assert!((out[1][i] - want.im[i]).abs() < 1e-3, "im[{i}]");
    }
}

#[test]
fn stage1_artifact_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::open(&dir).unwrap();
    let name = "fft_stage1_4x16";
    assert!(rt.has(name), "manifest should list {name}");

    let a = randomish(4, 16, 7);
    let f = dft_matrix(16);
    let t = twiddles(0, 4, 16, 64);
    let dims_a = [4i64, 16];
    let dims_f = [16i64, 16];
    let out = rt
        .execute_f32(
            name,
            &[
                (&a.re, &dims_a),
                (&a.im, &dims_a),
                (&f.re, &dims_f),
                (&f.im, &dims_f),
                (&t.re, &dims_a),
                (&t.im, &dims_a),
            ],
        )
        .unwrap();
    // Oracle: (A @ F) ⊙ T.
    let y = cmatmul_ref(&a, &f);
    for i in 0..4 * 16 {
        let wr = y.re[i] * t.re[i] - y.im[i] * t.im[i];
        let wi = y.re[i] * t.im[i] + y.im[i] * t.re[i];
        assert!((out[0][i] - wr).abs() < 1e-3, "re[{i}]: {} vs {wr}", out[0][i]);
        assert!((out[1][i] - wi).abs() < 1e-3, "im[{i}]");
    }
}

#[test]
fn executables_are_cached_and_reusable() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::open(&dir).unwrap();
    let f = dft_matrix(16);
    let a = randomish(16, 4, 1);
    let dims_f = [16i64, 16];
    let dims_a = [16i64, 4];
    let inputs: &[(&[f32], &[i64])] = &[
        (&f.re, &dims_f),
        (&f.im, &dims_f),
        (&a.re, &dims_a),
        (&a.im, &dims_a),
    ];
    let first = rt.execute_f32("fft_stage2_16x4", inputs).unwrap();
    // Second call hits the executable cache; results identical.
    let second = rt.execute_f32("fft_stage2_16x4", inputs).unwrap();
    assert_eq!(first, second);
}

#[test]
fn wrong_input_shape_is_an_error() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::open(&dir).unwrap();
    let bad = vec![0f32; 7];
    let dims = [16i64, 16];
    assert!(rt.execute_f32("fft_stage2_16x4", &[(&bad, &dims)]).is_err());
}

#[test]
fn fft_e2e_pjrt_backend_validates() {
    let Some(dir) = artifacts_dir() else { return };
    let rep = tuna::apps::fft::run_distributed_fft(
        &tuna::model::MachineProfile::fugaku(),
        4,
        2,
        16,
        16,
        &tuna::algos::AlgoKind::Tuna { radix: 2 },
        tuna::apps::fft::FftBackend::Pjrt { dir },
    )
    .unwrap();
    assert!(rep.max_err < 1e-4, "err {}", rep.max_err);
    assert!(rep.backend.contains("PJRT"));
    // All shapes present in the manifest: no naive fallback.
    assert!(!rep.backend.contains("fallback"), "{}", rep.backend);
}
