//! Analytic-model validation (DESIGN.md §6 (4)): the single-rank replay
//! estimator must track the exact threaded engine on statistically
//! symmetric workloads. These bounds are what justify using the model
//! for the paper-scale (P >= 8192) figure points.

use tuna::algos::{run_alltoallv, AlgoKind, GlobalAlgo, LocalAlgo};
use tuna::comm::{Engine, Topology};
use tuna::model::analytic::Estimator;
use tuna::model::MachineProfile;
use tuna::workload::{BlockSizes, Dist};

/// Relative error |model - engine| / engine.
fn rel_err(kind: AlgoKind, p: usize, q: usize, s: u64, profile: MachineProfile) -> f64 {
    let topo = Topology::new(p, q);
    let engine = Engine::new(profile.clone(), topo);
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: s }, 11);
    let measured = run_alltoallv(&engine, &kind, &sizes, false)
        .unwrap()
        .makespan;
    let est = Estimator::new(&profile, topo)
        .estimate(&kind, sizes.mean_size())
        .makespan;
    (est - measured).abs() / measured
}

#[test]
fn tuna_model_tracks_engine() {
    for (p, q, s) in [(64, 8, 512), (128, 8, 64), (128, 8, 4096), (256, 8, 1024)] {
        for r in [2usize, 8, 16] {
            let e = rel_err(AlgoKind::Tuna { radix: r }, p, q, s, MachineProfile::fugaku());
            assert!(
                e < 0.35,
                "tuna r={r} P={p} S={s}: model off by {:.0}%",
                e * 100.0
            );
        }
    }
}

#[test]
fn linear_model_tracks_engine() {
    for (p, q, s) in [(64, 8, 512), (128, 8, 2048)] {
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::Vendor,
            AlgoKind::Scattered { block_count: 8 },
            AlgoKind::Pairwise,
        ] {
            let e = rel_err(kind, p, q, s, MachineProfile::fugaku());
            assert!(
                e < 0.4,
                "{} P={p} S={s}: model off by {:.0}%",
                kind.name(),
                e * 100.0
            );
        }
    }
}

#[test]
fn hier_model_tracks_engine() {
    for (p, q, s) in [(64, 8, 512), (128, 8, 2048)] {
        for kind in [
            AlgoKind::hier_coalesced(4, 2),
            AlgoKind::hier_staggered(4, 8),
            AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Linear },
            AlgoKind::Hier {
                local: LocalAlgo::Tuna { radix: 4 },
                global: GlobalAlgo::Bruck { radix: 2 },
            },
            AlgoKind::Hier {
                local: LocalAlgo::Linear,
                global: GlobalAlgo::Bruck { radix: 4 },
            },
        ] {
            let e = rel_err(kind, p, q, s, MachineProfile::fugaku());
            assert!(
                e < 0.45,
                "{} P={p} S={s}: model off by {:.0}%",
                kind.name(),
                e * 100.0
            );
        }
    }
}

#[test]
fn model_preserves_algorithm_ordering() {
    // What matters for the figures is ordering: at small S the model must
    // rank tuna < scattered < naive burst linear, matching the engine.
    let p = 128;
    let q = 8;
    let profile = MachineProfile::fugaku();
    let topo = Topology::new(p, q);
    let engine = Engine::new(profile.clone(), topo);
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 64 }, 5);
    let est = Estimator::new(&profile, topo);
    let kinds = [
        AlgoKind::Tuna { radix: 2 },
        AlgoKind::Vendor,
        AlgoKind::OmpiLinear,
    ];
    let measured: Vec<f64> = kinds
        .iter()
        .map(|k| run_alltoallv(&engine, k, &sizes, false).unwrap().makespan)
        .collect();
    let modeled: Vec<f64> = kinds
        .iter()
        .map(|k| est.estimate(k, sizes.mean_size()).makespan)
        .collect();
    let order = |v: &[f64]| {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
        idx
    };
    assert_eq!(
        order(&measured),
        order(&modeled),
        "model must preserve algorithm ordering: engine {measured:?} vs model {modeled:?}"
    );
}
