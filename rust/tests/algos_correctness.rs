//! Gold correctness matrix: every algorithm, across process counts,
//! topologies, distributions and parameter settings, must deliver the
//! exact all-to-allv result — validated with real byte patterns
//! (DESIGN.md §6 (1)).

use tuna::algos::{hier, run_alltoallv, tuning, AlgoKind, GlobalAlgo, LocalAlgo};
use tuna::comm::{Engine, Topology};
use tuna::model::MachineProfile;
use tuna::util::prng::Pcg64;
use tuna::util::prop::forall;
use tuna::workload::{BlockSizes, Dist};

fn check(kind: AlgoKind, p: usize, q: usize, dist: Dist, seed: u64) {
    let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
    let sizes = BlockSizes::generate(p, dist, seed);
    let rep = run_alltoallv(&engine, &kind, &sizes, true)
        .unwrap_or_else(|e| panic!("{} P={p} Q={q} {dist:?}: {e}", kind.name()));
    assert!(rep.validated);
}

fn linear_kinds(p: usize) -> Vec<AlgoKind> {
    vec![
        AlgoKind::SpreadOut,
        AlgoKind::OmpiLinear,
        AlgoKind::Pairwise,
        AlgoKind::Scattered { block_count: 1 },
        AlgoKind::Scattered { block_count: 3 },
        AlgoKind::Scattered { block_count: p },
        AlgoKind::Vendor,
    ]
}

#[test]
fn linear_algorithms_all_topologies() {
    for (p, q) in [(8, 1), (8, 2), (8, 8), (12, 4), (7, 7), (9, 3), (16, 4)] {
        for kind in linear_kinds(p) {
            check(kind, p, q, Dist::Uniform { max: 256 }, 42);
        }
    }
}

#[test]
fn tuna_all_radices_small_p() {
    // Exhaustive radix sweep at small P — every radix from 2 to P.
    for p in [4usize, 6, 8, 9, 12] {
        for r in 2..=p {
            check(AlgoKind::Tuna { radix: r }, p, 1, Dist::Uniform { max: 128 }, p as u64);
        }
    }
}

#[test]
fn bruck2_matches_tuna_radix2_traffic() {
    // The two-phase non-uniform Bruck baseline is TuNA at radix 2:
    // identical round structure and traffic.
    let p = 16;
    let e = Engine::new(MachineProfile::test_flat(), Topology::flat(p));
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 512 }, 3);
    let a = run_alltoallv(&e, &AlgoKind::Bruck2, &sizes, false).unwrap();
    let b = run_alltoallv(&e, &AlgoKind::Tuna { radix: 2 }, &sizes, false).unwrap();
    assert_eq!(a.counters, b.counters);
    assert_eq!(a.rounds, b.rounds);
}

#[test]
fn hier_variants_parameter_grid() {
    for (p, q) in [(8, 2), (8, 4), (16, 4), (12, 3), (18, 6)] {
        let n = p / q;
        for radix in tuning::radix_candidates(q).into_iter().filter(|&r| r <= q) {
            for coalesced in [true, false] {
                let bc_max = if coalesced { (n - 1).max(1) } else { ((n - 1) * q).max(1) };
                for bc in [1, bc_max] {
                    let kind = if coalesced {
                        AlgoKind::hier_coalesced(radix, bc)
                    } else {
                        AlgoKind::hier_staggered(radix, bc)
                    };
                    check(kind, p, q, Dist::Uniform { max: 192 }, 7);
                }
            }
        }
    }
}

#[test]
fn hier_composition_grid() {
    // The full local×global cross product at a couple of topology
    // shapes: any local level must compose correctly with any global
    // level.
    for (p, q) in [(8usize, 2usize), (16, 4)] {
        let n = p / q;
        for local in [LocalAlgo::Tuna { radix: 2 }, LocalAlgo::Tuna { radix: q }, LocalAlgo::Linear]
        {
            for global in [
                GlobalAlgo::Coalesced { block_count: 1 },
                GlobalAlgo::Staggered { block_count: 2 },
                GlobalAlgo::Linear,
                GlobalAlgo::Bruck { radix: 2 },
                GlobalAlgo::Bruck { radix: n },
            ] {
                check(AlgoKind::Hier { local, global }, p, q, Dist::Uniform { max: 160 }, 11);
            }
        }
    }
}

#[test]
fn all_algorithms_all_distributions() {
    let dists = [
        Dist::Uniform { max: 1024 },
        Dist::normal_default(),
        Dist::powerlaw_default(),
        Dist::Const { size: 64 },
        Dist::FftN1,
        Dist::FftN2,
    ];
    let p = 16;
    let q = 4;
    let mut kinds = linear_kinds(p);
    kinds.extend([
        AlgoKind::Bruck2,
        AlgoKind::Tuna { radix: 4 },
        AlgoKind::Tuna { radix: 16 },
        AlgoKind::hier_coalesced(2, 2),
        AlgoKind::hier_staggered(4, 5),
        AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 2 } },
        AlgoKind::Hier { local: LocalAlgo::Tuna { radix: 2 }, global: GlobalAlgo::Linear },
    ]);
    for dist in dists {
        for kind in &kinds {
            check(*kind, p, q, dist, 99);
        }
    }
}

#[test]
fn property_random_configs_all_families() {
    forall("random algo/config correctness", 40, |rng| {
        let q_choices = [1usize, 2, 4];
        let q = q_choices[rng.next_below(3) as usize];
        let nodes = 1 + rng.next_below(4) as usize;
        let p = (q * nodes).max(2);
        let q = if p % q == 0 { q } else { 1 };
        let kind = random_kind(rng, p, q);
        let seed = rng.next_u64();
        let dist = Dist::Uniform {
            max: 8 * (1 + rng.next_below(64)),
        };
        let engine = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, dist, seed);
        match run_alltoallv(&engine, &kind, &sizes, true) {
            Ok(rep) if rep.validated => Ok(()),
            Ok(_) => Err(format!("{} invalid result", kind.name())),
            Err(e) => Err(format!("{} P={p} Q={q}: {e}", kind.name())),
        }
    });
}

fn random_kind(rng: &mut Pcg64, p: usize, q: usize) -> AlgoKind {
    loop {
        match rng.next_below(7) {
            0 => return AlgoKind::SpreadOut,
            1 => return AlgoKind::Pairwise,
            2 => {
                return AlgoKind::Scattered {
                    block_count: 1 + rng.next_below(p as u64) as usize,
                }
            }
            3 => {
                return AlgoKind::Tuna {
                    radix: (2 + rng.next_below(p as u64) as usize).min(p),
                }
            }
            4 => return AlgoKind::OmpiLinear,
            5 | 6 if q >= 2 && p / q >= 2 => {
                return hier::random_composition(rng, q, p / q)
            }
            _ => continue,
        }
    }
}

#[test]
fn conservation_total_bytes_delivered() {
    // The sum of delivered payload bytes equals the workload total for
    // every algorithm (no data lost or duplicated) — checked indirectly
    // by fingerprints, directly here via a Const workload's counters.
    let p = 12;
    let size = 100u64;
    let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, 4));
    let sizes = BlockSizes::generate(p, Dist::Const { size }, 0);
    for kind in [
        AlgoKind::SpreadOut,
        AlgoKind::Tuna { radix: 3 },
        AlgoKind::hier_coalesced(2, 1),
        AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 2 } },
    ] {
        let rep = run_alltoallv(&e, &kind, &sizes, true).unwrap();
        // Every rank must receive P blocks of `size` bytes; validation
        // inside run_alltoallv already asserts identity, so just confirm
        // the run moved at least the workload's bytes (log algorithms
        // move more via store-and-forward).
        let min_bytes = sizes.total_bytes() - (p as u64 * size); // minus self blocks
        assert!(
            rep.counters.total_bytes() >= min_bytes,
            "{}: moved {} < workload {}",
            kind.name(),
            rep.counters.total_bytes(),
            min_bytes
        );
    }
}
