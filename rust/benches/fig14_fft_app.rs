//! Bench harness for paper figure fig14 (quick grid; the full
//! paper-scale run is `tuna figure fig14 --full`). Prints the table and
//! the wallclock taken to regenerate it.

use tuna::harness::{run_figure, FigOpts};

fn main() {
    let opts = FigOpts::bench();
    let t0 = std::time::Instant::now();
    let tables = run_figure("fig14", &opts).expect("figure generation failed");
    for t in &tables {
        println!("{}", t.render());
    }
    println!(
        "bench fig14_fft_app: regenerated in {:.2} s (artifacts in {:?})",
        t0.elapsed().as_secs_f64(),
        opts.out_dir
    );
}
