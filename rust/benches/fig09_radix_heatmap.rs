//! Bench harness for paper figure fig9 (quick grid; the full
//! paper-scale run is `tuna figure fig9 --full`). Prints the table and
//! the wallclock taken to regenerate it.

use tuna::harness::{run_figure, FigOpts};

fn main() {
    let opts = FigOpts::bench();
    let t0 = std::time::Instant::now();
    let tables = run_figure("fig9", &opts).expect("figure generation failed");
    for t in &tables {
        println!("{}", t.render());
    }
    println!(
        "bench fig09_radix_heatmap: regenerated in {:.2} s (artifacts in {:?})",
        t0.elapsed().as_secs_f64(),
        opts.out_dir
    );
}
