//! L3 performance bench (DESIGN.md §7): host-side throughput of the
//! virtual-time engine — the hot path every figure and application run
//! goes through. Reports:
//!
//! * message throughput of the mailbox/clock core (ping-rounds over a
//!   rank pair and an 8-rank ring);
//! * whole-algorithm wallclock for representative (algo, P, dist, mode,
//!   exec) points — phantom *and* real payloads, threaded *and*
//!   plan/replay — with derived messages/second, the host copied-bytes
//!   counter (the zero-copy rope accounting, see `comm::buffer`), and on
//!   replay rows the compiled plan telemetry (`plan_ops`, peak per-rank
//!   plan bytes, the interned arena footprint `plan_bytes` with its
//!   `plan_programs` count, workload `nnz_total`, and the
//!   `replay_shards` the sharded executor auto-sized to). Replay rows
//!   include P >= 4096 dense points, the sparse P = 32768 acceptance
//!   point — whose plan op-count is asserted proportional to the
//!   nonzeros — and the PR 6 sparse P = 262144 point;
//! * a threaded-vs-replay radix *sweep* at P = 512 phantom (the selector
//!   refinement workload), recording the replay speedup per commit;
//! * a serial-vs-sharded *parallel replay* row over one cached plan
//!   (P = 262144 full / 32768 quick), recording the shard speedup with
//!   makespan bit-identity asserted in passing;
//! * a *persistent handle* row (the PR 7 acceptance point): 16 one-shot
//!   calls — fresh engine per call, so each pays plan compilation —
//!   against one `PersistentColl` started 16 times at P = 4096, with
//!   every makespan asserted bit-identical and the same-engine one-shot
//!   plan-cache contract (`hits == calls - 1`) asserted in passing;
//! * a serial-vs-parallel *plan compile* row (the PR 10 tentpole): the
//!   same sparse workload compiled by the serial packer and by the
//!   scoped-thread forge, plan equality asserted in passing, speedup
//!   recorded as `compile_speedup` (P = 65536 full / 16384 quick);
//! * a *plan interning* row (the PR 10 footprint acceptance point): a
//!   constant-size dense workload under spread-out at P = 32768
//!   (4096 quick), where every rank's program is a rotation of one
//!   canonical program — the interned arena is asserted to be <= 50%
//!   of the legacy `Vec<PlanOp>`-per-rank footprint;
//! * engine spawn overhead vs P.
//!
//! Besides the human-readable table, every run writes a machine-readable
//! perf trajectory to `BENCH_engine.json` (override with `--out <path>`)
//! so CI can archive per-commit numbers. `--quick` shrinks the grid to a
//! smoke-test size for CI.
//!
//! Used before/after every optimization in EXPERIMENTS.md §Perf; the
//! PR 2 acceptance point is `tuna(r=2)` at P = 512 in real mode, the
//! PR 3 acceptance points are the P = 512 sweep speedup (>= 10x
//! expected) and the P = 4096 replay row.

// Bench entry points mirror the engine's MPI-like positional signatures
// (the lib sets the same allow crate-wide).
#![allow(clippy::too_many_arguments)]

use std::time::Instant;

use tuna::algos::{run_alltoallv_mode, AlgoKind, ExecMode};
use tuna::comm::{DataBuf, Engine, Payload, PersistentColl, Topology};
use tuna::model::MachineProfile;
use tuna::workload::{BlockSizes, Dist};

fn bench_ping(pairs: usize, rounds: usize) -> f64 {
    let p = pairs * 2;
    let engine = Engine::new(MachineProfile::test_flat(), Topology::flat(p));
    let t0 = Instant::now();
    engine.run(|ctx| {
        let me = ctx.rank();
        let peer = me ^ 1;
        for r in 0..rounds {
            let _ = ctx.sendrecv(
                peer,
                (r % 1000) as u32,
                Payload::Raw(DataBuf::Phantom(64)),
                peer,
                (r % 1000) as u32,
            );
        }
    });
    let msgs = (p * rounds) as f64;
    msgs / t0.elapsed().as_secs_f64()
}

struct AlgoRow {
    algo: String,
    p: usize,
    q: usize,
    s: u64,
    dist: String,
    real: bool,
    exec: ExecMode,
    s_per_run: f64,
    sim_msgs_per_sec: f64,
    copied_bytes: u64,
    payload_bytes: u64,
    /// Plan-cache hits/misses over the whole row (warm-up + timed
    /// iterations) — replay rows compile once and hit `iters` times; a
    /// miss count above 1 would mean the timed loop re-compiled and the
    /// row stopped measuring cached replays.
    plan_hits: u64,
    plan_misses: u64,
    /// Replay rows: total compiled plan ops and the peak per-rank plan
    /// footprint in bytes (the per-row memory envelope). 0 on threaded
    /// rows, which compile nothing.
    plan_ops: u64,
    plan_row_bytes: u64,
    /// Replay rows: the interned arena's actual footprint and how many
    /// distinct rank programs it stores — `plan_bytes` vs the
    /// materialized `plan_ops * sizeof(PlanOp)` legacy envelope is the
    /// PR 10 compression ratio. 0 on threaded rows.
    plan_bytes: u64,
    plan_programs: u64,
    /// Total structural nonzeros of the workload (P² for dense rows).
    nnz_total: u64,
    /// Worker shards the replay executor ran with (the `replay-shards`
    /// auto policy — bit-identical for every value, recorded so the
    /// trajectory ties wallclock to the parallelism used). 0 on
    /// threaded rows.
    replay_shards: u64,
}

fn bench_algo(
    kind: AlgoKind,
    p: usize,
    q: usize,
    s: u64,
    dist: Dist,
    iters: usize,
    real: bool,
    exec: ExecMode,
) -> AlgoRow {
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let sizes = BlockSizes::generate(p, dist, 7);
    // Warm-up (also the counter source: virtual counters are identical
    // across runs, and copied_bytes only depends on the mode). For
    // replay, the warm-up compiles and caches the plan, so the timed
    // iterations measure cached replays — the FFT-style reuse pattern.
    let rep = run_alltoallv_mode(&engine, &kind, &sizes, real, exec).unwrap();
    let msgs = rep.counters.total_msgs() as f64;
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = run_alltoallv_mode(&engine, &kind, &sizes, real, exec).unwrap();
    }
    let per_run = t0.elapsed().as_secs_f64() / iters as f64;
    let (plan_hits, plan_misses) = engine.plan_cache.stats();
    // Plan telemetry after the stats read, so the extra cache hit below
    // does not perturb the hit/miss contract the rows assert.
    let (plan_ops, plan_row_bytes, plan_bytes, plan_programs) = if exec == ExecMode::Replay {
        let plan = tuna::algos::plan_for(&engine, &kind, &sizes).unwrap();
        let st = plan.stats();
        (
            plan.total_ops() as u64,
            plan.peak_rank_bytes() as u64,
            st.plan_bytes as u64,
            st.distinct_programs as u64,
        )
    } else {
        (0, 0, 0, 0)
    };
    AlgoRow {
        algo: kind.name(),
        p,
        q,
        s,
        dist: dist.name().to_string(),
        real,
        exec,
        s_per_run: per_run,
        sim_msgs_per_sec: msgs / per_run,
        copied_bytes: rep.counters.copied_bytes,
        payload_bytes: sizes.total_bytes(),
        plan_hits,
        plan_misses,
        plan_ops,
        plan_row_bytes,
        plan_bytes,
        plan_programs,
        nnz_total: sizes.total_nnz(),
        replay_shards: if exec == ExecMode::Replay {
            tuna::comm::replay::auto_shards(p) as u64
        } else {
            0
        },
    }
}

struct ParallelRow {
    p: usize,
    shards: usize,
    serial_s: f64,
    sharded_s: f64,
}

/// The PR 6 acceptance row: the same cached plan replayed by the
/// single-threaded executor and by the sharded executor, timed head to
/// head. Bit-identity of the makespan is asserted in passing — the
/// speedup is pure wallclock.
fn bench_parallel_replay(p: usize, q: usize, nnz: usize, shards: usize) -> ParallelRow {
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let kind = AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap();
    let sizes = BlockSizes::generate(p, Dist::Sparse { nnz, max: 1024 }, 7);
    let plan = tuna::algos::plan_for(&engine, &kind, &sizes).unwrap();
    let t0 = Instant::now();
    let serial = tuna::comm::replay::execute_sharded(&engine.profile, engine.topo, &plan, 1)
        .unwrap();
    let serial_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let sharded = tuna::comm::replay::execute_sharded(&engine.profile, engine.topo, &plan, shards)
        .unwrap();
    let sharded_s = t1.elapsed().as_secs_f64();
    assert_eq!(
        serial.makespan.to_bits(),
        sharded.makespan.to_bits(),
        "sharded replay diverged from serial at P={p}, shards={shards}"
    );
    ParallelRow { p, shards, serial_s, sharded_s }
}

struct PersistentRow {
    p: usize,
    calls: usize,
    algo: String,
    oneshot_s: f64,
    persistent_s: f64,
}

/// The PR 7 acceptance row: `calls` one-shot invocations with a fresh
/// engine per call — the `MPI_Alltoallv` usage pattern, where every
/// call pays plan compilation — against one persistent handle
/// (`alltoallv_init` pattern) started `calls` times over the frozen
/// plan. Every makespan (across one-shot calls, across starts, and
/// between the two sides) is asserted bit-identical, so the recorded
/// speedup is pure setup amortization, not a different schedule. The
/// same-engine plan-cache contract (`hits == calls - 1`, one miss) is
/// asserted in passing on a third, untimed loop.
fn bench_persistent(p: usize, q: usize, s: u64, calls: usize) -> PersistentRow {
    assert!(calls >= 2);
    let kind = AlgoKind::Tuna { radix: 2 };
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: s }, 7);

    // One-shot side: fresh engine per call, each compiles from scratch.
    let t0 = Instant::now();
    let mut makespan_bits = 0u64;
    for i in 0..calls {
        let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
        let rep = run_alltoallv_mode(&engine, &kind, &sizes, false, ExecMode::Replay).unwrap();
        if i == 0 {
            makespan_bits = rep.makespan.to_bits();
        } else {
            assert_eq!(rep.makespan.to_bits(), makespan_bits, "one-shot calls diverged at P={p}");
        }
    }
    let oneshot_s = t0.elapsed().as_secs_f64();

    // Persistent side: init once (compile + freeze), start `calls`
    // times. Init is outside the timed window by design — that is the
    // cost the handle exists to amortize.
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let handle = PersistentColl::init(&engine, kind, &sizes, false, ExecMode::Replay).unwrap();
    let t1 = Instant::now();
    for _ in 0..calls {
        let rep = handle.start_frozen().unwrap();
        assert_eq!(
            rep.makespan.to_bits(),
            makespan_bits,
            "persistent start diverged from one-shot at P={p}"
        );
    }
    let persistent_s = t1.elapsed().as_secs_f64();

    // Same-engine one-shot loop: the plan cache must miss exactly once
    // (first call compiles) and hit on every later call — the hoisting
    // contract the coordinator's measure loop relies on.
    let cached = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    for _ in 0..calls {
        let _ = run_alltoallv_mode(&cached, &kind, &sizes, false, ExecMode::Replay).unwrap();
    }
    assert_eq!(
        cached.plan_cache.stats(),
        (calls as u64 - 1, 1),
        "plan cache ineffective across same-engine one-shot calls at P={p}"
    );

    PersistentRow {
        p,
        calls,
        algo: kind.name(),
        oneshot_s,
        persistent_s,
    }
}

struct CompileRow {
    p: usize,
    algo: String,
    threads: usize,
    serial_s: f64,
    parallel_s: f64,
    plan_ops: u64,
}

/// The PR 10 tentpole row: one workload compiled by the serial packer
/// (`threads = 1`) and by the scoped-thread forge at the engine's
/// resolved worker count, timed head to head (best of three each, after
/// a warm-up pass). Representation-identity of the two plans is
/// asserted in passing — the recorded speedup buys the exact same plan
/// bytes, not a relaxed schedule.
fn bench_compile(p: usize, q: usize, nnz: usize) -> CompileRow {
    use tuna::algos::compile_plan_threads;
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let kind = AlgoKind::SpreadOut;
    let sizes = BlockSizes::generate(p, Dist::Sparse { nnz, max: 1024 }, 7);
    let threads = engine.compile_threads_for(p).max(2);
    let serial_plan = compile_plan_threads(&engine, &kind, &sizes, 1).unwrap();
    let parallel_plan = compile_plan_threads(&engine, &kind, &sizes, threads).unwrap();
    assert_eq!(
        serial_plan, parallel_plan,
        "parallel compile diverged from serial at P={p}, threads={threads}"
    );
    let best_of = |threads: usize| -> f64 {
        (0..3)
            .map(|_| {
                let t0 = Instant::now();
                let _ = compile_plan_threads(&engine, &kind, &sizes, threads).unwrap();
                t0.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min)
    };
    let serial_s = best_of(1);
    let parallel_s = best_of(threads);
    CompileRow {
        p,
        algo: kind.name(),
        threads,
        serial_s,
        parallel_s,
        plan_ops: serial_plan.total_ops() as u64,
    }
}

struct InternRow {
    p: usize,
    algo: String,
    total_ops: u64,
    programs: u64,
    plan_bytes: u64,
    legacy_bytes: u64,
}

/// The PR 10 footprint acceptance point: a constant-size dense workload
/// under a linear family, where every rank's program is a rotation of
/// one canonical program — the whole plan interns to a single shared
/// program and the arena footprint collapses from O(P²) materialized
/// ops to one program window plus the rank → program map. Asserted
/// <= 50% of the legacy footprint (in practice it is orders of
/// magnitude below).
fn bench_intern(p: usize, q: usize, size: u64) -> InternRow {
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let kind = AlgoKind::SpreadOut;
    let sizes = BlockSizes::generate(p, Dist::Const { size }, 7);
    let plan = tuna::algos::compile_plan(&engine, &kind, &sizes).unwrap();
    let st = plan.stats();
    assert!(
        2 * st.plan_bytes <= st.legacy_bytes,
        "interned plan {} B exceeds 50% of legacy {} B at P={p}",
        st.plan_bytes,
        st.legacy_bytes
    );
    InternRow {
        p,
        algo: kind.name(),
        total_ops: st.total_ops as u64,
        programs: st.distinct_programs as u64,
        plan_bytes: st.plan_bytes as u64,
        legacy_bytes: st.legacy_bytes as u64,
    }
}

struct SweepRow {
    p: usize,
    radices: Vec<usize>,
    threaded_s: f64,
    replay_s: f64,
}

/// The selector-refinement workload: a phantom radix sweep at one (P, Q,
/// S) point, threaded vs replayed. This is the model-sweep speedup the
/// plan/replay mode exists for.
fn bench_sweep(p: usize, q: usize, s: u64, radices: Vec<usize>) -> SweepRow {
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: s }, 7);
    let run_all = |exec: ExecMode| -> f64 {
        let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
        let t0 = Instant::now();
        for &r in &radices {
            let kind = AlgoKind::Tuna { radix: r };
            let _ = run_alltoallv_mode(&engine, &kind, &sizes, false, exec).unwrap();
        }
        t0.elapsed().as_secs_f64()
    };
    let threaded_s = run_all(ExecMode::Threaded);
    let replay_s = run_all(ExecMode::Replay);
    SweepRow {
        p,
        radices,
        threaded_s,
        replay_s,
    }
}

struct OverlapRow {
    p: usize,
    segments: usize,
    algo: String,
    blocking_makespan: f64,
    pipelined_makespan: f64,
    exposed_blocking: f64,
    exposed_pipelined: f64,
    overlap_speedup: f64,
}

/// The PR 9 acceptance row: one collective split into `segments` chunks
/// and replayed twice over the same workload — blocking stitch vs
/// pipelined stitch — with per-segment compute sized off a no-compute
/// probe (one segment's worth of communication each, the regime where a
/// pipeline can at best halve the critical path). The recorded numbers
/// are *virtual* makespans and exposure counters, so the speedup is a
/// property of the schedule, not of host wallclock.
fn bench_overlap(p: usize, q: usize, segments: usize) -> OverlapRow {
    use tuna::algos::{run_alltoallv_segmented_replay, SegmentCompute};
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let kind = AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap();
    let sizes = BlockSizes::generate(p, Dist::Sparse { nnz: 16, max: 1024 }, 7);
    let probe =
        run_alltoallv_segmented_replay(&engine, &kind, &sizes, segments, false, &SegmentCompute::None)
            .unwrap();
    let per_seg = SegmentCompute::Uniform(probe.makespan / segments as f64);
    let blocking =
        run_alltoallv_segmented_replay(&engine, &kind, &sizes, segments, false, &per_seg).unwrap();
    let pipelined =
        run_alltoallv_segmented_replay(&engine, &kind, &sizes, segments, true, &per_seg).unwrap();
    assert!(
        pipelined.makespan <= blocking.makespan,
        "pipelined stitch slower than blocking at P={p}: {} vs {}",
        pipelined.makespan,
        blocking.makespan
    );
    assert!(
        pipelined.counters.exposed_comm <= blocking.counters.exposed_comm,
        "pipelined stitch exposed more comm than blocking at P={p}"
    );
    OverlapRow {
        p,
        segments,
        algo: kind.name(),
        blocking_makespan: blocking.makespan,
        pipelined_makespan: pipelined.makespan,
        exposed_blocking: blocking.counters.exposed_comm,
        exposed_pipelined: pipelined.counters.exposed_comm,
        overlap_speedup: blocking.makespan / pipelined.makespan.max(1e-30),
    }
}

fn bench_spawn(p: usize) -> f64 {
    let engine = Engine::new(MachineProfile::test_flat(), Topology::flat(p));
    let t0 = Instant::now();
    engine.run(|_ctx| {});
    t0.elapsed().as_secs_f64()
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_engine.json".to_string());

    println!(
        "== perf_engine: L3 host-side throughput ({}) ==",
        if quick { "quick" } else { "full" }
    );

    let ping_grid: &[(usize, usize)] = if quick {
        &[(1, 2_000), (4, 500)]
    } else {
        &[(1, 20_000), (4, 5_000)]
    };
    let mut ping_rows: Vec<(usize, usize, f64)> = Vec::new();
    for &(pairs, rounds) in ping_grid {
        let rate = bench_ping(pairs, rounds);
        println!(
            "mailbox ping  {:>2} pairs x {:>6} rounds: {:>10.0} msgs/s",
            pairs, rounds, rate
        );
        ping_rows.push((pairs, rounds, rate));
    }

    // (kind, p, q, s, dist, iters, real, exec). The real-mode
    // tuna(r=2)@512 row is the PR 2 acceptance point (payload ropes);
    // the threaded/replay pairs record the PR 3 executor speedup, the
    // replay-only tail rows are P counts thread-per-rank never ran, and
    // the sparse P=32768 row is the PR 5 acceptance point (O(nnz)
    // plans past the dense replay wall).
    let thr = ExecMode::Threaded;
    let rpl = ExecMode::Replay;
    let uni = Dist::Uniform { max: 1024 };
    let uni256 = Dist::Uniform { max: 256 };
    let sparse16 = Dist::Sparse { nnz: 16, max: 1024 };
    type GridRow = (AlgoKind, usize, usize, u64, Dist, usize, bool, ExecMode);
    let sparse_point = (
        AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap(),
        32768usize,
        64usize,
        1024u64,
        sparse16,
        1usize,
        false,
        rpl,
    );
    let algo_grid: Vec<GridRow> = if quick {
        vec![
            (AlgoKind::Tuna { radix: 2 }, 64, 8, 1024, uni, 3, false, thr),
            (AlgoKind::Tuna { radix: 2 }, 64, 8, 1024, uni, 3, false, rpl),
            (AlgoKind::Tuna { radix: 2 }, 64, 8, 1024, uni, 3, true, thr),
            (AlgoKind::SpreadOut, 64, 8, 1024, uni, 3, true, thr),
            (AlgoKind::hier_coalesced(2, 4), 64, 8, 1024, uni, 3, true, thr),
            (AlgoKind::parse("hier:l=linear,g=bruck:r=2").unwrap(), 64, 8, 1024, uni, 3, false, rpl),
            (AlgoKind::Tuna { radix: 2 }, 512, 32, 1024, uni, 2, false, thr),
            (AlgoKind::Tuna { radix: 2 }, 512, 32, 1024, uni, 2, false, rpl),
            (AlgoKind::Tuna { radix: 2 }, 4096, 32, 256, uni256, 1, false, rpl),
            sparse_point,
        ]
    } else {
        vec![
            (AlgoKind::Tuna { radix: 2 }, 256, 8, 1024, uni, 3, false, thr),
            (AlgoKind::Tuna { radix: 2 }, 256, 8, 1024, uni, 3, false, rpl),
            (AlgoKind::Tuna { radix: 16 }, 256, 8, 1024, uni, 3, false, thr),
            (AlgoKind::SpreadOut, 256, 8, 1024, uni, 3, false, thr),
            (AlgoKind::SpreadOut, 256, 8, 1024, uni, 3, false, rpl),
            (AlgoKind::Vendor, 256, 8, 1024, uni, 3, false, thr),
            (AlgoKind::hier_coalesced(2, 4), 256, 8, 1024, uni, 3, false, thr),
            (AlgoKind::hier_coalesced(2, 4), 256, 8, 1024, uni, 3, false, rpl),
            (AlgoKind::parse("hier:l=linear,g=bruck:r=2").unwrap(), 256, 8, 1024, uni, 3, false, rpl),
            (AlgoKind::Tuna { radix: 2 }, 256, 8, 1024, uni, 3, true, thr),
            (AlgoKind::hier_coalesced(2, 4), 256, 8, 1024, uni, 3, true, thr),
            (AlgoKind::Tuna { radix: 2 }, 512, 32, 1024, uni, 2, true, thr),
            (AlgoKind::Tuna { radix: 2 }, 1024, 32, 256, uni256, 1, false, thr),
            (AlgoKind::Tuna { radix: 2 }, 1024, 32, 256, uni256, 2, false, rpl),
            (AlgoKind::Tuna { radix: 2 }, 4096, 32, 256, uni256, 2, false, rpl),
            (AlgoKind::Tuna { radix: 4 }, 8192, 32, 64, Dist::Uniform { max: 64 }, 1, false, rpl),
            (AlgoKind::SpreadOut, 8192, 64, 1024, sparse16, 1, false, rpl),
            sparse_point,
            (
                AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap(),
                32768,
                64,
                1024,
                Dist::Sparse { nnz: 64, max: 1024 },
                1,
                false,
                rpl,
            ),
            // PR 6 acceptance point: exact sparse replay a further 8x past
            // the PR 5 wall, carried by the sharded executor.
            (
                AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap(),
                262_144,
                64,
                1024,
                sparse16,
                1,
                false,
                rpl,
            ),
        ]
    };

    println!(
        "\n{:<28} {:>6} {:>8} {:>5} {:>9} {:>12} {:>14} {:>14} {:>9} {:>12} {:>10} {:>12} {:>7}",
        "algorithm", "P", "dist", "mode", "exec", "s/run", "sim-msgs/s", "copied-B",
        "plan-h/m", "plan-ops", "row-bytes", "plan-bytes", "progs"
    );
    let mut algo_rows: Vec<AlgoRow> = Vec::new();
    for (kind, p, q, s, dist, iters, real, exec) in algo_grid {
        let row = bench_algo(kind, p, q, s, dist, iters, real, exec);
        println!(
            "{:<28} {:>6} {:>8} {:>5} {:>9} {:>10.3} s {:>14.0} {:>14} {:>5}/{} {:>12} {:>10} {:>12} {:>7}",
            row.algo,
            row.p,
            row.dist,
            if row.real { "real" } else { "phtm" },
            row.exec.name(),
            row.s_per_run,
            row.sim_msgs_per_sec,
            row.copied_bytes,
            row.plan_hits,
            row.plan_misses,
            row.plan_ops,
            row.plan_row_bytes,
            row.plan_bytes,
            row.plan_programs
        );
        if row.real {
            assert_eq!(
                row.copied_bytes,
                2 * row.payload_bytes,
                "zero-copy invariant violated for {}",
                row.algo
            );
        }
        if row.exec == ExecMode::Replay {
            assert_eq!(
                row.copied_bytes, 0,
                "replay moved host payload bytes for {}",
                row.algo
            );
            // One compile at warm-up, then every timed iteration replays
            // the cached plan — the cache-effectiveness contract this
            // bench exists to record.
            assert_eq!(
                (row.plan_hits, row.plan_misses),
                (iters as u64, 1),
                "plan cache ineffective for {}",
                row.algo
            );
            assert!(row.plan_ops > 0, "replay row {} recorded no plan ops", row.algo);
            if row.dist == "sparse" {
                // The PR 5 acceptance shape: sparse plan op-count is
                // proportional to the total nonzeros, not P².
                assert!(
                    row.plan_ops <= 64 * row.nnz_total,
                    "{}: sparse plan {} ops exceeds 64 x nnz ({})",
                    row.algo,
                    row.plan_ops,
                    row.nnz_total
                );
            }
        }
        algo_rows.push(row);
    }

    // Threaded-vs-replay model sweep at P = 512 phantom (the PR 3
    // acceptance point: >= 10x expected).
    let sweep = if quick {
        bench_sweep(512, 32, 1024, vec![2, 4, 16, 512])
    } else {
        bench_sweep(512, 32, 1024, vec![2, 4, 8, 16, 23, 32, 64, 128, 256, 512])
    };
    let speedup = sweep.threaded_s / sweep.replay_s.max(1e-12);
    println!(
        "\nmodel sweep P={} ({} radixes): threaded {:.3} s, replay {:.3} s — {:.1}x speedup",
        sweep.p,
        sweep.radices.len(),
        sweep.threaded_s,
        sweep.replay_s,
        speedup
    );

    // Serial-vs-sharded replay of one cached plan (the PR 6 executor).
    let par = if quick {
        bench_parallel_replay(32_768, 64, 16, 4)
    } else {
        bench_parallel_replay(262_144, 64, 16, 8)
    };
    let par_speedup = par.serial_s / par.sharded_s.max(1e-12);
    println!(
        "\nparallel replay P={} sparse: serial {:.3} s, {} shards {:.3} s — {:.1}x speedup",
        par.p, par.serial_s, par.shards, par.sharded_s, par_speedup
    );

    // Persistent handle vs one-shot (the PR 7 acceptance point). The
    // same point in quick and full mode: the acceptance criterion is
    // P = 4096, 16 calls.
    let pers = bench_persistent(4096, 32, 256, 16);
    let pers_speedup = pers.oneshot_s / pers.persistent_s.max(1e-12);
    println!(
        "\npersistent P={} {} x{} calls: one-shot {:.3} s, persistent {:.3} s — {:.1}x speedup",
        pers.p, pers.algo, pers.calls, pers.oneshot_s, pers.persistent_s, pers_speedup
    );
    assert!(
        pers_speedup >= 2.0,
        "persistent handle speedup {pers_speedup:.2}x below the 2x acceptance bar"
    );

    // Serial-vs-parallel plan compilation over one sparse workload (the
    // PR 10 tentpole): the forge must buy wallclock without changing a
    // byte of the plan.
    let comp = if quick {
        bench_compile(16_384, 64, 16)
    } else {
        bench_compile(65_536, 64, 16)
    };
    let comp_speedup = comp.serial_s / comp.parallel_s.max(1e-12);
    println!(
        "\nplan compile P={} {} ({} ops): serial {:.4} s, {} threads {:.4} s — {:.1}x speedup",
        comp.p, comp.algo, comp.plan_ops, comp.serial_s, comp.threads, comp.parallel_s, comp_speedup
    );

    // Interned-arena footprint on the workload class it targets (the
    // PR 10 acceptance point): constant-size dense rows under a linear
    // family intern to one shared program.
    let intern = if quick {
        bench_intern(4096, 32, 1024)
    } else {
        bench_intern(32_768, 64, 1024)
    };
    println!(
        "plan interning P={} {} dense const: {} ops in {} program(s), {} B interned vs {} B legacy ({:.4}% ratio)",
        intern.p,
        intern.algo,
        intern.total_ops,
        intern.programs,
        intern.plan_bytes,
        intern.legacy_bytes,
        100.0 * intern.plan_bytes as f64 / intern.legacy_bytes.max(1) as f64
    );

    // Segmented overlap vs blocking over one collective (the PR 9
    // acceptance point): virtual-schedule speedup plus the exposed-comm
    // reduction, at P = 4096 in both quick and full mode.
    let ovl = bench_overlap(4096, 32, 4);
    println!(
        "\noverlap P={} {} K={}: blocking {:.6} s, pipelined {:.6} s — {:.2}x; \
         exposed {:.6} -> {:.6} s",
        ovl.p,
        ovl.algo,
        ovl.segments,
        ovl.blocking_makespan,
        ovl.pipelined_makespan,
        ovl.overlap_speedup,
        ovl.exposed_blocking,
        ovl.exposed_pipelined
    );

    println!();
    let spawn_grid: &[usize] = if quick { &[64, 256] } else { &[64, 256, 1024, 4096] };
    let mut spawn_rows: Vec<(usize, f64)> = Vec::new();
    for &p in spawn_grid {
        let t = bench_spawn(p);
        println!(
            "engine spawn+join P={:<5}: {:>8.1} ms ({:.1} us/rank)",
            p,
            t * 1e3,
            t * 1e6 / p as f64
        );
        spawn_rows.push((p, t));
    }

    // ---- machine-readable trajectory -----------------------------------
    let mut j = String::from("{\n  \"bench\": \"perf_engine\",\n");
    j.push_str(&format!(
        "  \"mode\": \"{}\",\n",
        if quick { "quick" } else { "full" }
    ));
    j.push_str("  \"mailbox\": [\n");
    for (i, (pairs, rounds, rate)) in ping_rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"pairs\": {pairs}, \"rounds\": {rounds}, \"msgs_per_sec\": {rate:.1}}}{}\n",
            if i + 1 < ping_rows.len() { "," } else { "" }
        ));
    }
    j.push_str("  ],\n  \"algos\": [\n");
    for (i, r) in algo_rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"algo\": \"{}\", \"p\": {}, \"q\": {}, \"s\": {}, \"dist\": \"{}\", \
             \"real\": {}, \
             \"exec\": \"{}\", \"s_per_run\": {:.6}, \"sim_msgs_per_sec\": {:.1}, \
             \"copied_bytes\": {}, \"payload_bytes\": {}, \
             \"plan_hits\": {}, \"plan_misses\": {}, \
             \"plan_ops\": {}, \"plan_row_bytes\": {}, \
             \"plan_bytes\": {}, \"plan_programs\": {}, \"nnz_total\": {}, \
             \"replay_shards\": {}}}{}\n",
            json_escape(&r.algo),
            r.p,
            r.q,
            r.s,
            json_escape(&r.dist),
            r.real,
            r.exec.name(),
            r.s_per_run,
            r.sim_msgs_per_sec,
            r.copied_bytes,
            r.payload_bytes,
            r.plan_hits,
            r.plan_misses,
            r.plan_ops,
            r.plan_row_bytes,
            r.plan_bytes,
            r.plan_programs,
            r.nnz_total,
            r.replay_shards,
            if i + 1 < algo_rows.len() { "," } else { "" }
        ));
    }
    j.push_str("  ],\n");
    j.push_str(&format!(
        "  \"sweep\": {{\"p\": {}, \"radix_count\": {}, \"threaded_s\": {:.6}, \
         \"replay_s\": {:.6}, \"replay_speedup\": {:.2}}},\n",
        sweep.p,
        sweep.radices.len(),
        sweep.threaded_s,
        sweep.replay_s,
        speedup
    ));
    j.push_str(&format!(
        "  \"parallel_replay\": {{\"p\": {}, \"shards\": {}, \"serial_s\": {:.6}, \
         \"sharded_s\": {:.6}, \"speedup\": {:.2}}},\n",
        par.p, par.shards, par.serial_s, par.sharded_s, par_speedup
    ));
    j.push_str(&format!(
        "  \"persistent_speedup\": {{\"p\": {}, \"calls\": {}, \"algo\": \"{}\", \
         \"oneshot_s\": {:.6}, \"persistent_s\": {:.6}, \"speedup\": {:.2}}},\n",
        pers.p,
        pers.calls,
        json_escape(&pers.algo),
        pers.oneshot_s,
        pers.persistent_s,
        pers_speedup
    ));
    j.push_str(&format!(
        "  \"compile_speedup\": {{\"p\": {}, \"algo\": \"{}\", \"threads\": {}, \
         \"plan_ops\": {}, \"serial_s\": {:.6}, \"parallel_s\": {:.6}, \"speedup\": {:.2}}},\n",
        comp.p,
        json_escape(&comp.algo),
        comp.threads,
        comp.plan_ops,
        comp.serial_s,
        comp.parallel_s,
        comp_speedup
    ));
    j.push_str(&format!(
        "  \"plan_interning\": {{\"p\": {}, \"algo\": \"{}\", \"total_ops\": {}, \
         \"distinct_programs\": {}, \"plan_bytes\": {}, \"legacy_bytes\": {}, \
         \"ratio\": {:.6}}},\n",
        intern.p,
        json_escape(&intern.algo),
        intern.total_ops,
        intern.programs,
        intern.plan_bytes,
        intern.legacy_bytes,
        intern.plan_bytes as f64 / intern.legacy_bytes.max(1) as f64
    ));
    j.push_str(&format!(
        "  \"overlap_speedup\": {{\"p\": {}, \"segments\": {}, \"algo\": \"{}\", \
         \"blocking_makespan\": {:.9}, \"pipelined_makespan\": {:.9}, \
         \"exposed_blocking\": {:.9}, \"exposed_pipelined\": {:.9}, \"speedup\": {:.2}}},\n",
        ovl.p,
        ovl.segments,
        json_escape(&ovl.algo),
        ovl.blocking_makespan,
        ovl.pipelined_makespan,
        ovl.exposed_blocking,
        ovl.exposed_pipelined,
        ovl.overlap_speedup
    ));
    j.push_str("  \"spawn\": [\n");
    for (i, (p, t)) in spawn_rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"p\": {p}, \"seconds\": {t:.6}}}{}\n",
            if i + 1 < spawn_rows.len() { "," } else { "" }
        ));
    }
    j.push_str("  ]\n}\n");

    match std::fs::write(&out_path, &j) {
        Ok(()) => println!("\nperf trajectory written to {out_path}"),
        Err(e) => eprintln!("\nwarning: could not write {out_path}: {e}"),
    }
}
