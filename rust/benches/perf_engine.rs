//! L3 performance bench (DESIGN.md §7): host-side throughput of the
//! virtual-time engine — the hot path every figure and application run
//! goes through. Reports:
//!
//! * message throughput of the mailbox/clock core (ping-rounds over a
//!   rank pair and an 8-rank ring);
//! * whole-algorithm wallclock for representative (algo, P) points, with
//!   derived messages/second;
//! * engine spawn overhead vs P.
//!
//! Used before/after every optimization in EXPERIMENTS.md §Perf.

use std::time::Instant;

use tuna::algos::{run_alltoallv, AlgoKind};
use tuna::comm::{DataBuf, Engine, Payload, Topology};
use tuna::model::MachineProfile;
use tuna::workload::{BlockSizes, Dist};

fn bench_ping(pairs: usize, rounds: usize) -> f64 {
    let p = pairs * 2;
    let engine = Engine::new(MachineProfile::test_flat(), Topology::flat(p));
    let t0 = Instant::now();
    engine.run(|ctx| {
        let me = ctx.rank();
        let peer = me ^ 1;
        for r in 0..rounds {
            let _ = ctx.sendrecv(
                peer,
                (r % 1000) as u32,
                Payload::Raw(DataBuf::Phantom(64)),
                peer,
                (r % 1000) as u32,
            );
        }
    });
    let msgs = (p * rounds) as f64;
    msgs / t0.elapsed().as_secs_f64()
}

fn bench_algo(kind: AlgoKind, p: usize, q: usize, s: u64, iters: usize) -> (f64, f64) {
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(p, q));
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: s }, 7);
    // Warm-up.
    let rep = run_alltoallv(&engine, &kind, &sizes, false).unwrap();
    let msgs = rep.counters.total_msgs() as f64;
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = run_alltoallv(&engine, &kind, &sizes, false).unwrap();
    }
    let per_run = t0.elapsed().as_secs_f64() / iters as f64;
    (per_run, msgs / per_run)
}

fn bench_spawn(p: usize) -> f64 {
    let engine = Engine::new(MachineProfile::test_flat(), Topology::flat(p));
    let t0 = Instant::now();
    engine.run(|_ctx| {});
    t0.elapsed().as_secs_f64()
}

fn main() {
    println!("== perf_engine: L3 host-side throughput ==");

    for (pairs, rounds) in [(1usize, 20_000usize), (4, 5_000)] {
        let rate = bench_ping(pairs, rounds);
        println!(
            "mailbox ping  {:>2} pairs x {:>6} rounds: {:>10.0} msgs/s",
            pairs, rounds, rate
        );
    }

    println!(
        "\n{:<28} {:>6} {:>12} {:>14}",
        "algorithm", "P", "s/run", "sim-msgs/s"
    );
    for (kind, p, q, s, iters) in [
        (AlgoKind::Tuna { radix: 2 }, 256usize, 8usize, 1024u64, 3usize),
        (AlgoKind::Tuna { radix: 16 }, 256, 8, 1024, 3),
        (AlgoKind::SpreadOut, 256, 8, 1024, 3),
        (AlgoKind::Vendor, 256, 8, 1024, 3),
        (AlgoKind::TunaHierCoalesced { radix: 2, block_count: 4 }, 256, 8, 1024, 3),
        (AlgoKind::Tuna { radix: 2 }, 1024, 32, 256, 1),
    ] {
        let (per_run, rate) = bench_algo(kind, p, q, s, iters);
        println!(
            "{:<28} {:>6} {:>10.3} s {:>14.0}",
            kind.name(),
            p,
            per_run,
            rate
        );
    }

    println!();
    for p in [64usize, 256, 1024, 4096] {
        let t = bench_spawn(p);
        println!(
            "engine spawn+join P={:<5}: {:>8.1} ms ({:.1} us/rank)",
            p,
            t * 1e3,
            t * 1e6 / p as f64
        );
    }
}
