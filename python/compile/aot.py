"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (one per static shape the Rust FFT app may request):
  fft_stage1_{rows}x{n2}.hlo.txt   (A @ F_n2) ⊙ T
  fft_stage2_{n1}x{cols}.hlo.txt   F_n1 @ A

plus `manifest.tsv` (name \t path \t info) read by
`rust/src/runtime/manifest.rs`. Python runs only here — never on the Rust
request path.

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shapes covering the examples and the quick/full harness runs:
# (rows_per_rank, n2) for stage 1; (n1, cols_per_rank) for stage 2.
STAGE1_SHAPES = [
    (8, 64),   # N=64x64, P=8 (fft_e2e default)
    (8, 60),   # N=64x60, P=8 (non-uniform column split)
    (8, 32),   # N=32xX, P=4
    (4, 16),   # N=16x16, P=4 (quickstart-scale)
    (16, 16),
]
STAGE2_SHAPES = [
    (64, 8),   # N=64x64, P=8
    (64, 7),   # N=64x60, P=8 (60 = 4*8 + 4*7)
    (32, 8),
    (16, 4),
    (16, 5),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side can `to_tuple()` uniformly)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage1(rows, n2):
    spec_a = jax.ShapeDtypeStruct((rows, n2), jnp.float32)
    spec_f = jax.ShapeDtypeStruct((n2, n2), jnp.float32)
    lowered = jax.jit(model.fft_stage1).lower(
        spec_a, spec_a, spec_f, spec_f, spec_a, spec_a
    )
    return to_hlo_text(lowered)


def lower_stage2(n1, cols):
    spec_f = jax.ShapeDtypeStruct((n1, n1), jnp.float32)
    spec_a = jax.ShapeDtypeStruct((n1, cols), jnp.float32)
    lowered = jax.jit(model.fft_stage2).lower(spec_f, spec_f, spec_a, spec_a)
    return to_hlo_text(lowered)


def build_artifacts(out_dir, stage1_shapes=None, stage2_shapes=None, verbose=True):
    """Lower all configured shapes into `out_dir`; returns manifest rows."""
    stage1_shapes = STAGE1_SHAPES if stage1_shapes is None else stage1_shapes
    stage2_shapes = STAGE2_SHAPES if stage2_shapes is None else stage2_shapes
    os.makedirs(out_dir, exist_ok=True)
    rows = []

    for m, n in stage1_shapes:
        name = f"fft_stage1_{m}x{n}"
        path = f"{name}.hlo.txt"
        text = lower_stage1(m, n)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        info = f"(A[{m},{n}] @ F[{n},{n}]) * T[{m},{n}] f32 -> (re, im)"
        rows.append((name, path, info))
        if verbose:
            print(f"  {name}: {len(text)} chars")

    for n1, c in stage2_shapes:
        name = f"fft_stage2_{n1}x{c}"
        path = f"{name}.hlo.txt"
        text = lower_stage2(n1, c)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        info = f"F[{n1},{n1}] @ A[{n1},{c}] f32 -> (re, im)"
        rows.append((name, path, info))
        if verbose:
            print(f"  {name}: {len(text)} chars")

    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# name\tpath\tinfo — written by python/compile/aot.py\n")
        for name, path, info in rows:
            f.write(f"{name}\t{path}\t{info}\n")
    if verbose:
        print(f"wrote {len(rows)} artifacts + manifest to {out_dir}")
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    args = ap.parse_args()
    build_artifacts(args.out)


if __name__ == "__main__":
    main()
