"""Layer-1 Pallas kernels: the local DFT stages of the 4-step FFT.

Complex arithmetic in split re/im layout (four real matmuls per complex
matmul) — the MXU-friendly formulation: each `jnp.dot` inside the kernel
maps onto the systolic array, and the twiddle multiply is fused into the
same kernel so the intermediate never round-trips through HBM.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): operand tiles are
placed in VMEM by `pallas_call`'s BlockSpecs; at the shapes the FFT app
uses (rows-per-rank x n2 <= 64x64 f32) the whole working set is ~200 KiB,
far under the ~16 MiB VMEM budget, so a single-block grid is optimal —
tiling would only add copy overhead. `interpret=True` everywhere: the CPU
PJRT plugin cannot execute Mosaic custom-calls; lowering through the
interpreter produces plain HLO that both jaxlib and the Rust PJRT client
execute identically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stage1_kernel(a_re_ref, a_im_ref, f_re_ref, f_im_ref, t_re_ref, t_im_ref,
                   o_re_ref, o_im_ref):
    """o = (A @ F) ⊙ T, complex, fused."""
    a_re = a_re_ref[...]
    a_im = a_im_ref[...]
    f_re = f_re_ref[...]
    f_im = f_im_ref[...]
    # Four real matmuls (MXU) for the complex product.
    y_re = jnp.dot(a_re, f_re, preferred_element_type=jnp.float32) - jnp.dot(
        a_im, f_im, preferred_element_type=jnp.float32)
    y_im = jnp.dot(a_re, f_im, preferred_element_type=jnp.float32) + jnp.dot(
        a_im, f_re, preferred_element_type=jnp.float32)
    # Fused twiddle (VPU elementwise) — no HBM round-trip.
    t_re = t_re_ref[...]
    t_im = t_im_ref[...]
    o_re_ref[...] = y_re * t_re - y_im * t_im
    o_im_ref[...] = y_re * t_im + y_im * t_re


@functools.partial(jax.jit, static_argnames=())
def fft_stage1(a_re, a_im, f_re, f_im, t_re, t_im):
    """Pallas call: stage 1 of the 4-step FFT for one rank's row block.

    a: (rows, n2) local rows; f: (n2, n2) DFT matrix; t: (rows, n2)
    twiddles. Returns (rows, n2) split complex.
    """
    m, n = a_re.shape
    out = jax.ShapeDtypeStruct((m, n), jnp.float32)
    return pl.pallas_call(
        _stage1_kernel,
        out_shape=(out, out),
        interpret=True,
    )(a_re, a_im, f_re, f_im, t_re, t_im)


def _stage2_kernel(f_re_ref, f_im_ref, a_re_ref, a_im_ref, o_re_ref, o_im_ref):
    """o = F @ A, complex."""
    f_re = f_re_ref[...]
    f_im = f_im_ref[...]
    a_re = a_re_ref[...]
    a_im = a_im_ref[...]
    o_re_ref[...] = jnp.dot(f_re, a_re, preferred_element_type=jnp.float32) - jnp.dot(
        f_im, a_im, preferred_element_type=jnp.float32)
    o_im_ref[...] = jnp.dot(f_re, a_im, preferred_element_type=jnp.float32) + jnp.dot(
        f_im, a_re, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def fft_stage2(f_re, f_im, a_re, a_im):
    """Pallas call: stage 2 — column DFT for one rank's column block.

    f: (n1, n1) DFT matrix; a: (n1, cols). Returns (n1, cols).
    """
    n1, cols = a_re.shape
    out = jax.ShapeDtypeStruct((n1, cols), jnp.float32)
    return pl.pallas_call(
        _stage2_kernel,
        out_shape=(out, out),
        interpret=True,
    )(f_re, f_im, a_re, a_im)
