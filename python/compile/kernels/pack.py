"""Layer-1 Pallas kernel: segmented gather ("pack").

The send-buffer assembly hot path of TuNA: every round packs the moving
data blocks into a contiguous send buffer. On TPU this is a VMEM gather
driven by a precomputed index vector (the offsets the metadata phase
communicates); here it is expressed as a Pallas kernel and checked against
the pure-jnp oracle.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(data_ref, idx_ref, out_ref):
    idx = idx_ref[...]
    out_ref[...] = data_ref[idx]


@jax.jit
def pack(data, idx):
    """out[i] = data[idx[i]] for int32 `idx`; shapes static. A zero-length
    index (a round with nothing to pack) short-circuits — the Pallas
    interpreter cannot grid over empty outputs."""
    (m,) = idx.shape
    if m == 0:
        return jnp.zeros((0,), dtype=data.dtype)
    return pl.pallas_call(
        _pack_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), data.dtype),
        interpret=True,
    )(data, idx)
