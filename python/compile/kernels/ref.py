"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(`python/tests/test_kernels.py`) sweeps shapes with hypothesis and asserts
allclose between kernel and oracle. The oracles are also what the Rust
side's naive DFT fallback mirrors.
"""

import jax.numpy as jnp


def complex_matmul_ref(a_re, a_im, b_re, b_im):
    """(A @ B) for complex matrices in split re/im layout."""
    out_re = a_re @ b_re - a_im @ b_im
    out_im = a_re @ b_im + a_im @ b_re
    return out_re, out_im


def fft_stage1_ref(a_re, a_im, f_re, f_im, t_re, t_im):
    """Stage 1 of the 4-step FFT: (A @ F_n2) ⊙ T (complex Hadamard)."""
    y_re, y_im = complex_matmul_ref(a_re, a_im, f_re, f_im)
    out_re = y_re * t_re - y_im * t_im
    out_im = y_re * t_im + y_im * t_re
    return out_re, out_im


def fft_stage2_ref(f_re, f_im, a_re, a_im):
    """Stage 2 of the 4-step FFT: F_n1 @ A."""
    return complex_matmul_ref(f_re, f_im, a_re, a_im)


def pack_ref(data, idx):
    """Segmented gather: out[i] = data[idx[i]] — the send-buffer packing
    primitive (TuNA's per-round block assembly)."""
    return data[idx]


def dft_matrix(n, dtype=jnp.float32):
    """F_n[j, k] = exp(-2πi·jk/n) in split layout."""
    j = jnp.arange(n)[:, None].astype(jnp.float64)
    k = jnp.arange(n)[None, :].astype(jnp.float64)
    ang = -2.0 * jnp.pi * j * k / n
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def twiddles(row0, rows, n2, n_total, dtype=jnp.float32):
    """T[j, k] = exp(-2πi·(row0+j)·k / n_total) in split layout."""
    j = (row0 + jnp.arange(rows))[:, None].astype(jnp.float64)
    k = jnp.arange(n2)[None, :].astype(jnp.float64)
    ang = -2.0 * jnp.pi * j * k / n_total
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)
