"""Layer-2 JAX model: the FFT application's compute graph, built on the
Layer-1 Pallas kernels.

`fft_stage1` / `fft_stage2` are the per-rank functions `aot.py` lowers to
HLO text (one artifact per static shape); `local_fft4` composes the whole
4-step pipeline in one process — the model-level correctness check against
`jnp.fft.fft`.
"""

import jax.numpy as jnp

from .kernels import dft, ref


def fft_stage1(a_re, a_im, f_re, f_im, t_re, t_im):
    """(A @ F_n2) ⊙ T — one rank's stage-1 compute (Pallas kernel)."""
    return dft.fft_stage1(a_re, a_im, f_re, f_im, t_re, t_im)


def fft_stage2(f_re, f_im, a_re, a_im):
    """F_n1 @ A — one rank's stage-2 compute (Pallas kernel)."""
    return dft.fft_stage2(f_re, f_im, a_re, a_im)


def local_fft4(x_re, x_im, n1, n2):
    """Full 4-step FFT of a length n1*n2 signal on one process.

    Layout: M[j1, j2] = x[j1 + n1*j2]; result X[k2 + n2*k1] = out[k1, k2].
    Used by tests to validate the stage composition against jnp.fft.fft.
    """
    n_total = n1 * n2
    assert x_re.shape == (n_total,)
    m_re = x_re.reshape(n2, n1).T  # M[j1, j2]
    m_im = x_im.reshape(n2, n1).T

    f2_re, f2_im = ref.dft_matrix(n2)
    t_re, t_im = ref.twiddles(0, n1, n2, n_total)
    z_re, z_im = fft_stage1(m_re, m_im, f2_re, f2_im, t_re, t_im)

    f1_re, f1_im = ref.dft_matrix(n1)
    o_re, o_im = fft_stage2(f1_re, f1_im, z_re, z_im)  # out[k1, k2]

    # X[k2 + n2*k1] = out[k1, k2]
    return o_re.reshape(-1), o_im.reshape(-1)


def local_fft4_complex(x, n1, n2):
    """Complex-dtype convenience wrapper around `local_fft4`."""
    re, im = local_fft4(jnp.real(x).astype(jnp.float32),
                        jnp.imag(x).astype(jnp.float32), n1, n2)
    return re + 1j * im
