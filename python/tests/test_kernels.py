"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; every property asserts allclose between the
interpret-mode Pallas kernel and `ref.py`. This is the core correctness
signal for the compute layer (DESIGN.md §6 (5))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dft, pack, ref

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=24)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def rand(rng, *shape):
    return jnp.asarray(rng.uniform(-1, 1, size=shape), dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, seed=seeds)
def test_stage1_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    a_re, a_im = rand(rng, m, n), rand(rng, m, n)
    f_re, f_im = rand(rng, n, n), rand(rng, n, n)
    t_re, t_im = rand(rng, m, n), rand(rng, m, n)
    k_re, k_im = dft.fft_stage1(a_re, a_im, f_re, f_im, t_re, t_im)
    r_re, r_im = ref.fft_stage1_ref(a_re, a_im, f_re, f_im, t_re, t_im)
    np.testing.assert_allclose(k_re, r_re, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(k_im, r_im, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n1=dims, c=dims, seed=seeds)
def test_stage2_matches_ref(n1, c, seed):
    rng = np.random.default_rng(seed)
    f_re, f_im = rand(rng, n1, n1), rand(rng, n1, n1)
    a_re, a_im = rand(rng, n1, c), rand(rng, n1, c)
    k_re, k_im = dft.fft_stage2(f_re, f_im, a_re, a_im)
    r_re, r_im = ref.fft_stage2_ref(f_re, f_im, a_re, a_im)
    np.testing.assert_allclose(k_re, r_re, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(k_im, r_im, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=1, max_value=128),
       m=st.integers(min_value=1, max_value=64),
       seed=seeds)
def test_pack_matches_ref(n, m, seed):
    rng = np.random.default_rng(seed)
    data = rand(rng, n)
    idx = jnp.asarray(rng.integers(0, n, size=m), dtype=jnp.int32)
    np.testing.assert_array_equal(pack.pack(data, idx), ref.pack_ref(data, idx))


def test_stage1_with_real_dft_inputs():
    """Stage 1 with genuine F/T recovers per-row DFTs (impulse rows)."""
    n2, rows, n_total = 8, 4, 32
    a_re = jnp.zeros((rows, n2)).at[:, 0].set(1.0)  # impulse in each row
    a_im = jnp.zeros((rows, n2))
    f_re, f_im = ref.dft_matrix(n2)
    t_re = jnp.ones((rows, n2))
    t_im = jnp.zeros((rows, n2))
    o_re, o_im = dft.fft_stage1(a_re, a_im, f_re, f_im, t_re, t_im)
    # DFT of impulse = all ones.
    np.testing.assert_allclose(o_re, jnp.ones((rows, n2)), atol=1e-5)
    np.testing.assert_allclose(o_im, jnp.zeros((rows, n2)), atol=1e-5)
    del n_total


def test_kernels_handle_zero_imag():
    rng = np.random.default_rng(0)
    a_re = rand(rng, 3, 5)
    z = jnp.zeros((3, 5))
    f_re, f_im = ref.dft_matrix(5)
    t_re, t_im = ref.twiddles(0, 3, 5, 15)
    k = dft.fft_stage1(a_re, z, f_re, f_im, t_re, t_im)
    r = ref.fft_stage1_ref(a_re, z, f_re, f_im, t_re, t_im)
    np.testing.assert_allclose(k[0], r[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(k[1], r[1], rtol=1e-5, atol=1e-6)


def test_pack_empty_index():
    data = jnp.arange(4, dtype=jnp.float32)
    idx = jnp.asarray([], dtype=jnp.int32)
    assert pack.pack(data, idx).shape == (0,)


@pytest.mark.parametrize("n", [1, 2, 7, 16])
def test_dft_matrix_unitary_upto_scale(n):
    f_re, f_im = ref.dft_matrix(n)
    f = np.asarray(f_re) + 1j * np.asarray(f_im)
    eye = f @ f.conj().T / n
    np.testing.assert_allclose(eye, np.eye(n), atol=1e-4)
