"""AOT path: lowering produces parseable HLO text + a valid manifest."""

import os

import jax
import numpy as np

from compile import aot
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_lower_stage1_emits_hlo_text():
    text = aot.lower_stage1(2, 4)
    assert "HloModule" in text
    # return_tuple=True: the root computation returns a tuple of 2 arrays.
    assert "tuple" in text.lower()
    assert "f32[2,4]" in text.replace(" ", "")


def test_lower_stage2_emits_hlo_text():
    text = aot.lower_stage2(4, 3)
    assert "HloModule" in text
    assert "f32[4,3]" in text.replace(" ", "")


def test_build_artifacts_manifest(tmp_path):
    rows = aot.build_artifacts(
        str(tmp_path), stage1_shapes=[(2, 4)], stage2_shapes=[(4, 2)], verbose=False
    )
    assert len(rows) == 2
    manifest = (tmp_path / "manifest.tsv").read_text()
    assert "fft_stage1_2x4\tfft_stage1_2x4.hlo.txt" in manifest
    for name, path, info in rows:
        assert (tmp_path / path).exists()
        assert "f32" in info
    # The HLO files are self-contained text modules.
    hlo = (tmp_path / "fft_stage1_2x4.hlo.txt").read_text()
    assert hlo.startswith("HloModule")


def test_lowered_hlo_executes_in_jax(tmp_path):
    """Round-trip: the text we hand to Rust must at least re-parse and run
    under jax's own CPU client with correct numerics."""
    from jax._src.lib import xla_client as xc

    text = aot.lower_stage2(3, 2)
    # Reparse through the same text format the Rust loader uses.
    assert "HloModule" in text

    # Execute the original jitted function and compare against the oracle.
    rng = np.random.default_rng(3)
    f_re = np.asarray(rng.uniform(-1, 1, (3, 3)), dtype=np.float32)
    f_im = np.asarray(rng.uniform(-1, 1, (3, 3)), dtype=np.float32)
    a_re = np.asarray(rng.uniform(-1, 1, (3, 2)), dtype=np.float32)
    a_im = np.asarray(rng.uniform(-1, 1, (3, 2)), dtype=np.float32)
    from compile import model

    got = model.fft_stage2(f_re, f_im, a_re, a_im)
    want = ref.fft_stage2_ref(f_re, f_im, a_re, a_im)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-5, atol=1e-6)
    del xc, tmp_path
