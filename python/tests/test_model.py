"""Layer-2 correctness: the 4-step composition equals jnp.fft.fft."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("n1,n2", [(4, 4), (8, 4), (4, 8), (16, 16), (8, 6)])
def test_local_fft4_matches_jnp_fft(n1, n2):
    rng = np.random.default_rng(n1 * 100 + n2)
    n = n1 * n2
    x = rng.uniform(-1, 1, n) + 1j * rng.uniform(-1, 1, n)
    got = model.local_fft4_complex(jnp.asarray(x, dtype=jnp.complex64), n1, n2)
    want = np.fft.fft(x)
    scale = np.abs(want).max()
    np.testing.assert_allclose(
        np.asarray(got), want, atol=2e-4 * max(scale, 1.0), rtol=0
    )


@settings(max_examples=15, deadline=None)
@given(
    n1=st.integers(min_value=2, max_value=12),
    n2=st.integers(min_value=2, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_local_fft4_property(n1, n2, seed):
    rng = np.random.default_rng(seed)
    n = n1 * n2
    x = rng.uniform(-1, 1, n) + 1j * rng.uniform(-1, 1, n)
    got = np.asarray(model.local_fft4_complex(jnp.asarray(x, dtype=jnp.complex64), n1, n2))
    want = np.fft.fft(x)
    scale = max(np.abs(want).max(), 1.0)
    np.testing.assert_allclose(got, want, atol=3e-4 * scale, rtol=0)


def test_fft_of_constant_signal():
    # DFT of a constant is an impulse at k=0 of height N.
    n1, n2 = 4, 6
    n = n1 * n2
    x = jnp.ones(n, dtype=jnp.complex64)
    got = np.asarray(model.local_fft4_complex(x, n1, n2))
    want = np.zeros(n, dtype=np.complex128)
    want[0] = n
    np.testing.assert_allclose(got, want, atol=1e-4 * n)


def test_linearity():
    n1, n2 = 4, 4
    n = n1 * n2
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.uniform(-1, 1, n), dtype=jnp.complex64)
    b = jnp.asarray(rng.uniform(-1, 1, n), dtype=jnp.complex64)
    fa = np.asarray(model.local_fft4_complex(a, n1, n2))
    fb = np.asarray(model.local_fft4_complex(b, n1, n2))
    fab = np.asarray(model.local_fft4_complex(a + 2 * b, n1, n2))
    np.testing.assert_allclose(fab, fa + 2 * fb, atol=1e-3)
