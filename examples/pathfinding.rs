//! Graph-mining example (§VI-B): transitive closure of a scale-free
//! digraph via semi-naive fixed point, with the per-iteration shuffle
//! running through each of the paper's algorithms in turn — demonstrating
//! drop-in substitution for MPI_Alltoallv.
//!
//!     cargo run --release --example pathfinding

use tuna::algos::AlgoKind;
use tuna::apps::tc::{run_tc, sequential_tc};
use tuna::comm::{Engine, Topology};
use tuna::model::MachineProfile;
use tuna::util::stats::fmt_time;
use tuna::workload::graph::Graph;

fn main() -> tuna::Result<()> {
    let graph = Graph::scale_free(400, 2, 7);
    let expect = sequential_tc(&graph);
    println!(
        "graph: {} vertices, {} edges; sequential |TC| = {expect}",
        graph.n,
        graph.edges.len()
    );

    let engine = Engine::new(MachineProfile::polaris(), Topology::new(16, 4));
    let algos = [
        AlgoKind::Vendor,
        AlgoKind::SpreadOut,
        AlgoKind::Tuna { radix: 2 },
        AlgoKind::Tuna { radix: 8 },
        AlgoKind::hier_coalesced(2, 1),
        AlgoKind::hier_staggered(2, 4),
    ];
    let mut vendor_comm = None;
    println!(
        "{:<36} {:>6} {:>12} {:>12} {:>9}",
        "algorithm", "iters", "comm", "total", "speedup"
    );
    for kind in algos {
        let rep = run_tc(&engine, &kind, &graph, true)?;
        assert_eq!(rep.paths, expect);
        let speedup = vendor_comm
            .map(|v: f64| format!("{:.2}x", v / rep.comm_time))
            .unwrap_or_else(|| "1.00x".into());
        if matches!(kind, AlgoKind::Vendor) {
            vendor_comm = Some(rep.comm_time);
        }
        println!(
            "{:<36} {:>6} {:>12} {:>12} {:>9}",
            kind.name(),
            rep.iterations,
            fmt_time(rep.comm_time),
            fmt_time(rep.makespan),
            speedup
        );
    }
    println!("every run validated against the sequential oracle");
    Ok(())
}
