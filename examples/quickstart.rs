//! Quickstart: run one non-uniform all-to-all with TuNA on a simulated
//! Fugaku-like machine, validate the result, and compare against the
//! vendor MPI_Alltoallv baseline.
//!
//!     cargo run --release --example quickstart

use tuna::algos::{run_alltoallv, AlgoKind};
use tuna::comm::{Engine, Topology};
use tuna::model::MachineProfile;
use tuna::util::stats::fmt_time;
use tuna::workload::{BlockSizes, Dist};

fn main() -> tuna::Result<()> {
    // 256 ranks, 8 per node, Fugaku-like latency/bandwidth hierarchy.
    let engine = Engine::new(MachineProfile::fugaku(), Topology::new(256, 8));

    // Non-uniform workload: block sizes uniform in [0, 256 B] — the
    // small-message regime where the paper reports its largest gains.
    let sizes = BlockSizes::generate(256, Dist::Uniform { max: 256 }, 42);
    println!(
        "workload: P=256, Q=8, uniform block sizes <= 256 B ({} total)",
        tuna::util::stats::fmt_bytes(sizes.total_bytes())
    );

    // TuNA with radix 2 (small-message latency regime, per §V-A). Real
    // payloads: every byte is pattern-checked at its destination.
    let tuna = run_alltoallv(&engine, &AlgoKind::Tuna { radix: 2 }, &sizes, true)?;
    println!(
        "tuna(r=2):        {}  (validated={}, {} rounds, T peak {} slots)",
        fmt_time(tuna.makespan),
        tuna.validated,
        tuna.rounds,
        tuna.t_peak
    );

    // The vendor baseline (MPICH-style throttled linear alltoallv).
    let vendor = run_alltoallv(&engine, &AlgoKind::Vendor, &sizes, true)?;
    println!("vendor alltoallv: {}", fmt_time(vendor.makespan));
    println!("speedup: {:.2}x", vendor.makespan / tuna.makespan);

    // Hierarchical coalesced composition — the paper's overall winner
    // (spec `hier:l=tuna:r=2,g=coalesced:b=2`).
    let hier = run_alltoallv(&engine, &AlgoKind::hier_coalesced(2, 2), &sizes, true)?;
    println!(
        "hier(l=tuna(r=2),g=coalesced(b=2)): {}  ({:.2}x over vendor)",
        fmt_time(hier.makespan),
        vendor.makespan / hier.makespan
    );
    Ok(())
}
