//! END-TO-END driver (DESIGN.md deliverable (b)): distributed 4-step FFT
//! on a real small workload, composing every layer of the stack:
//!
//!   L1  Pallas DFT kernels (python/compile/kernels/dft.py)
//!   L2  JAX stage graphs  (python/compile/model.py)
//!   AOT HLO text artifacts (python/compile/aot.py -> artifacts/)
//!   RT  Rust PJRT client   (rust/src/runtime)
//!   L3  TuNA / TuNA_l^g transpose on the virtual-time engine
//!
//! Runs a 64x64 (uniform split) and a 64x60 (non-uniform, FFTW-style)
//! problem over 8 ranks, for several all-to-all algorithms, validating
//! every result against a sequential f64 DFT oracle and reporting the
//! simulated comm/compute split. Requires `make artifacts`; falls back to
//! the naive Rust backend with a notice otherwise.
//!
//!     make artifacts && cargo run --release --example fft_e2e

use tuna::algos::AlgoKind;
use tuna::apps::fft::{run_distributed_fft, FftBackend};
use tuna::model::MachineProfile;
use tuna::util::stats::fmt_time;

fn main() -> tuna::Result<()> {
    let profile = MachineProfile::fugaku();
    let algos = [
        AlgoKind::Vendor,
        AlgoKind::Tuna { radix: 4 },
        AlgoKind::hier_coalesced(2, 1),
    ];

    for (n1, n2) in [(64usize, 64usize), (64, 60)] {
        println!(
            "=== distributed FFT N = {n1} x {n2} = {} (P=8, Q=4) ===",
            n1 * n2
        );
        let mut vendor_comm = None;
        for kind in &algos {
            let rep = run_distributed_fft(&profile, 8, 4, n1, n2, kind, FftBackend::auto())?;
            let speedup = vendor_comm
                .map(|v: f64| format!("  comm speedup {:.2}x", v / rep.comm_time))
                .unwrap_or_default();
            if matches!(kind, AlgoKind::Vendor) {
                vendor_comm = Some(rep.comm_time);
            }
            println!(
                "  {:<34} err {:.2e}  total {}  comm {}  compute {}{}",
                kind.name(),
                rep.max_err,
                fmt_time(rep.makespan),
                fmt_time(rep.comm_time),
                fmt_time(rep.compute_time),
                speedup
            );
            if kind == algos.last().unwrap() {
                println!("  backend: {}", rep.backend);
            }
        }
    }
    println!("all results validated against the sequential f64 DFT oracle");
    Ok(())
}
