//! Radix-tuning walkthrough (§V-A): sweep TuNA's radix across message
//! sizes to surface the paper's three performance trends, compare the
//! measured ideal with the §V-A heuristic, and autotune the hierarchical
//! variants.
//!
//!     cargo run --release --example radix_tuning

use tuna::algos::{tuning, AlgoKind};
use tuna::comm::{Engine, Topology};
use tuna::coordinator::{measure, RunConfig};
use tuna::model::MachineProfile;
use tuna::workload::{BlockSizes, Dist};

fn main() -> tuna::Result<()> {
    let p = 256;
    let q = 8;
    let profile = MachineProfile::polaris();

    println!("TuNA radix sweep on {} (P={p}, Q={q})", profile.name);
    println!(
        "{:>8}  {:>7}  {:>12}  {:>9}",
        "S (B)", "ideal r", "t(ideal)", "heuristic"
    );
    for s in [16u64, 256, 1024, 8192, 65536] {
        let cfg = RunConfig {
            p,
            q,
            profile: profile.clone(),
            dist: Dist::Uniform { max: s },
            iters: 3,
            ..RunConfig::default()
        };
        let mut best = (0usize, f64::INFINITY);
        for r in tuning::radix_candidates(p) {
            let t = measure(&cfg, &AlgoKind::Tuna { radix: r })?.median();
            if t < best.1 {
                best = (r, t);
            }
        }
        let heur = tuning::heuristic_radix(p, s as f64 / 2.0);
        println!(
            "{:>8}  {:>7}  {:>9.3} ms  {:>9}",
            s,
            best.0,
            best.1 * 1e3,
            heur
        );
    }

    println!("\nautotuning the hierarchical variants at S=512:");
    let engine = Engine::new(profile, Topology::new(p, q));
    let sizes = BlockSizes::generate(p, Dist::Uniform { max: 512 }, 1);
    for coalesced in [true, false] {
        let res = tuning::autotune_hier(&engine, &sizes, coalesced)?;
        println!(
            "  {}: best {} at {:.3} ms (swept {} configs)",
            if coalesced { "coalesced" } else { "staggered" },
            res.best.name(),
            res.best_time * 1e3,
            res.sweep.len()
        );
    }
    Ok(())
}
